"""Tests for the service-oriented scheduling stack: PredictionService cache
correctness, policy/budget-manager equivalence with the legacy monolith
(bit-for-bit, every policy, multiple seeds), and EventEngine streaming +
multi-device behavior."""
import itertools

import numpy as np
import pytest

from repro.configs.paper_suite import PAPER_APPS
from repro.core import (
    CorrelationIndex, EnergyTimePredictor, EngineHooks, EventEngine,
    PredictionService, PredictorConfig, Testbed, V5E_DVFS, build_dataset,
    make_workload, profile_features, run_schedule, stream_workload,
)
from repro.core.features import clock_features
from repro.core.gbdt import GBDTParams
from repro.core.policies import (POLICIES, POLICY_NAMES, MinEnergy,
                                 QueueAwareBudget, resolve_policy)
from repro.core.scheduler import POLICIES as POLICY_TUPLE, legacy_run_schedule

APPS = list(PAPER_APPS)[:8]   # subset keeps the fit fast; behavior-identical
SMALL = PredictorConfig(
    gbdt=GBDTParams(iterations=80, depth=3, learning_rate=0.15,
                    l2_leaf_reg=5.0),
    gbdt_time=GBDTParams(iterations=80, depth=3, learning_rate=0.15,
                         l2_leaf_reg=3.0),
)


@pytest.fixture(scope="module")
def testbed():
    return Testbed(seed=0)


@pytest.fixture(scope="module")
def fitted(testbed):
    X, yp, yt, _ = build_dataset(APPS, testbed, seed=0)
    return EnergyTimePredictor(SMALL).fit(X, yp, yt)


@pytest.fixture(scope="module")
def app_feats(testbed):
    rng = np.random.default_rng(7)
    return {a.name: profile_features(a, testbed, rng=rng) for a in APPS}


def _assert_identical(a, b):
    assert a.policy == b.policy
    assert len(a.records) == len(b.records)
    for ra, rb in zip(a.records, b.records):
        assert ra == rb, (ra, rb)


# ---------------------------------------------------------------------- #
#  Equivalence: new stack == legacy monolith, bit-for-bit
# ---------------------------------------------------------------------- #
class TestEquivalence:
    def test_every_policy_every_seed(self, testbed, fitted, app_feats):
        """All six policies, 3 seeds: identical ExecutionRecord streams."""
        for pol, seed in itertools.product(POLICY_NAMES, range(3)):
            jobs = make_workload(APPS, testbed, seed=seed)
            kw = dict(predictor=fitted, app_features=app_feats)
            a = legacy_run_schedule(jobs, pol, Testbed(seed=100 + seed), **kw)
            b = run_schedule(jobs, pol, Testbed(seed=100 + seed), **kw)
            _assert_identical(a, b)

    def test_budget_manager_ablations(self, testbed, fitted, app_feats):
        """queue_aware / virtual_pacing off-switches match legacy exactly."""
        jobs = make_workload(APPS, testbed, seed=1)
        variants = [
            dict(queue_aware=False, virtual_pacing=False),
            dict(queue_aware=True, virtual_pacing=False),
            dict(queue_aware=False, virtual_pacing=True),
            dict(queue_aware=True, virtual_pacing=True, slack_share=0.6),
        ]
        for kw in variants:
            a = legacy_run_schedule(jobs, "d-dvfs", Testbed(seed=100),
                                    predictor=fitted,
                                    app_features=app_feats, **kw)
            b = run_schedule(jobs, "d-dvfs", Testbed(seed=100),
                             predictor=fitted, app_features=app_feats, **kw)
            _assert_identical(a, b)

    def test_with_correlation_index(self, testbed, fitted, app_feats):
        """Paper §III-D indirection path: correlated features, same records."""
        names = list(app_feats)
        F = np.stack([app_feats[n] for n in names])
        idx = CorrelationIndex(k=4, random_state=0).fit(names, F)
        jobs = make_workload(APPS, testbed, seed=2)
        kw = dict(predictor=fitted, app_features=app_feats, corr_index=idx,
                  corr_features=app_feats)
        a = legacy_run_schedule(jobs, "d-dvfs", Testbed(seed=100), **kw)
        b = run_schedule(jobs, "d-dvfs", Testbed(seed=100), **kw)
        _assert_identical(a, b)

    def test_multi_device(self, testbed, fitted, app_feats):
        for nd in (2, 4):
            jobs = make_workload(APPS, testbed, seed=3)
            kw = dict(predictor=fitted, app_features=app_feats, n_devices=nd)
            a = legacy_run_schedule(jobs, "min-energy", Testbed(seed=100),
                                    **kw)
            b = run_schedule(jobs, "min-energy", Testbed(seed=100), **kw)
            _assert_identical(a, b)

    def test_no_predictor_baselines(self, testbed):
        jobs = make_workload(APPS, testbed, seed=4)
        for pol in ("dc", "mc"):
            a = legacy_run_schedule(jobs, pol, Testbed(seed=100))
            b = run_schedule(jobs, pol, Testbed(seed=100))
            _assert_identical(a, b)

    def test_shared_service_across_runs(self, testbed, fitted, app_feats):
        """A reused service (warm caches) must not change results."""
        service = PredictionService(V5E_DVFS, predictor=fitted,
                                    app_features=app_feats, testbed=testbed)
        for seed in range(2):
            jobs = make_workload(APPS, testbed, seed=seed)
            a = legacy_run_schedule(jobs, "min-energy", Testbed(seed=100),
                                    predictor=fitted, app_features=app_feats)
            b = run_schedule(jobs, "min-energy", Testbed(seed=100),
                             service=service)
            _assert_identical(a, b)
        # warm reuse: one table build per distinct app across both runs
        assert service.stats.table_builds <= len(APPS)
        assert service.stats.table_hits > 0

    def test_feedback_disabled_still_identical(self, testbed, fitted,
                                               app_feats):
        """PR 2 frozen-path guarantee: a service with an attached (but
        observation-free) corrector AND a disabled OnlineAdapter feedback
        sink must reproduce the legacy monolith bit-for-bit."""
        from repro.core import ObservationStore, OnlineAdapter, RLSCorrector
        jobs = make_workload(APPS, testbed, seed=5)
        kw = dict(predictor=fitted, app_features=app_feats)
        a = legacy_run_schedule(jobs, "min-energy", Testbed(seed=100), **kw)

        service = PredictionService(V5E_DVFS, predictor=fitted,
                                    app_features=app_feats, testbed=testbed)
        service.attach_corrector(RLSCorrector(ObservationStore()))
        b = run_schedule(jobs, "min-energy", Testbed(seed=100),
                         service=service)
        _assert_identical(a, b)

        service2 = PredictionService(V5E_DVFS, predictor=fitted,
                                     app_features=app_feats, testbed=testbed)
        adapter = OnlineAdapter(service2, enabled=False)
        c = run_schedule(jobs, "min-energy", Testbed(seed=100),
                         service=service2, feedback=adapter)
        _assert_identical(a, c)
        assert adapter.n_observed == 0


# ---------------------------------------------------------------------- #
#  PredictionService
# ---------------------------------------------------------------------- #
class TestPredictionService:
    def _service(self, fitted, app_feats, testbed=None, **kw):
        return PredictionService(V5E_DVFS, predictor=fitted,
                                 app_features=app_feats, testbed=testbed,
                                 **kw)

    def test_table_matches_direct_predictor(self, fitted, app_feats):
        svc = self._service(fitted, app_feats)
        name = APPS[0].name
        tab = svc.table(name)
        X = np.stack([
            np.concatenate([app_feats[name], clock_features(c, V5E_DVFS)])
            for c in V5E_DVFS.clock_list()
        ])
        np.testing.assert_array_equal(tab.P, fitted.predict_power(X))
        np.testing.assert_array_equal(tab.T, fitted.predict_time(X))
        assert len(tab) == len(V5E_DVFS.clock_list())

    def test_one_build_per_app(self, fitted, app_feats):
        svc = self._service(fitted, app_feats)
        for _ in range(5):
            for a in APPS:
                svc.table(a.name)
        assert svc.stats.table_builds == len(APPS)
        assert svc.stats.table_hits == 4 * len(APPS)
        # cached tables are the same object — no recompute, no copy
        assert svc.table(APPS[0].name) is svc.table(APPS[0].name)

    def test_point_predictions_match_direct(self, fitted, app_feats):
        svc = self._service(fitted, app_feats)
        name = APPS[1].name
        for fn, clock in ((svc.t_min, V5E_DVFS.max_clock),
                          (svc.t_dc, V5E_DVFS.default_clock)):
            x = np.concatenate([app_feats[name],
                                clock_features(clock, V5E_DVFS)])
            assert fn(name) == float(fitted.predict_time(x[None])[0])
            fn(name)   # second call: cached
        assert svc.stats.point_predictions == 2

    def test_truth_table_matches_testbed(self, fitted, app_feats, testbed):
        svc = self._service(fitted, app_feats, testbed=testbed)
        app = APPS[2]
        tab = svc.truth_table(app)
        assert tab.source == "truth"
        for i, c in enumerate(tab.clocks):
            assert tab.T[i] == testbed.true_time(app, c)
            assert tab.P[i] == testbed.true_power(app, c)
        svc.truth_table(app)
        assert svc.stats.truth_builds == 1 and svc.stats.truth_hits == 1

    def test_truth_without_testbed_raises(self, fitted, app_feats):
        svc = self._service(fitted, app_feats, testbed=None)
        with pytest.raises(ValueError, match="testbed"):
            svc.truth_table(APPS[0])

    def test_correlated_apps_share_tables(self, fitted, app_feats):
        names = list(app_feats)
        F = np.stack([app_feats[n] for n in names])
        idx = CorrelationIndex(k=2, random_state=0).fit(names, F)
        svc = PredictionService(V5E_DVFS, predictor=fitted,
                                app_features=app_feats, corr_index=idx,
                                corr_features=app_feats)
        for n in names:
            svc.table(n)
        # every table key is a correlate; distinct correlates ≤ distinct apps
        assert svc.stats.table_builds <= len(names)
        for n in names:
            key, feats = svc.resolve(n)
            assert key[0] == "corr"
            np.testing.assert_array_equal(feats, app_feats[key[1]])

    def test_kernel_routing_matches_numpy(self, fitted, app_feats):
        """Forced Pallas path (interpret on CPU) ≈ numpy reference."""
        svc_np = self._service(fitted, app_feats, use_kernel=False)
        svc_k = self._service(fitted, app_feats, use_kernel=True)
        name = APPS[0].name
        t_np, t_k = svc_np.table(name), svc_k.table(name)
        assert svc_k.stats.kernel_batches == 2   # power + time
        np.testing.assert_allclose(t_k.P, t_np.P, rtol=2e-4)
        np.testing.assert_allclose(t_k.T, t_np.T, rtol=2e-4)


# ---------------------------------------------------------------------- #
#  EventEngine
# ---------------------------------------------------------------------- #
class TestEventEngine:
    def test_streaming_generator_matches_list(self, testbed, fitted,
                                              app_feats):
        """The engine consumes a generator lazily; results match the same
        jobs materialized up front."""
        def jobs_stream():
            return stream_workload(APPS, testbed, n_jobs=60, seed=5,
                                   n_devices=2)

        materialized = list(jobs_stream())
        kw = dict(predictor=fitted, app_features=app_feats, n_devices=2)
        a = run_schedule(materialized, "min-energy", Testbed(seed=100), **kw)
        b = run_schedule(jobs_stream(), "min-energy", Testbed(seed=100), **kw)
        _assert_identical(a, b)
        assert len(a.records) == 60

    def test_out_of_order_stream_rejected(self, testbed):
        jobs = list(stream_workload(APPS, testbed, n_jobs=5, seed=0))
        jobs[2], jobs[4] = jobs[4], jobs[2]
        with pytest.raises(ValueError, match="out of order"):
            run_schedule(iter(jobs), "dc", Testbed(seed=0))

    def test_multi_device_edf_dispatch(self, testbed, fitted, app_feats):
        """8 devices: all jobs run once, per-device spans never overlap, EDF
        respected among simultaneously-queued jobs, per-device clock state
        tracked."""
        jobs = list(stream_workload(APPS, testbed, n_jobs=120, seed=6,
                                    n_devices=8))
        service = PredictionService(V5E_DVFS, predictor=fitted,
                                    app_features=app_feats, testbed=testbed)
        engine = EventEngine(testbed, MinEnergy(V5E_DVFS), service=service,
                             n_devices=8, seed=100)
        r = engine.run(jobs)
        assert sorted(x.job_id for x in r.records) == sorted(
            j.job_id for j in jobs)
        by_dev = {}
        for x in r.records:
            by_dev.setdefault(x.device, []).append(x)
        assert len(by_dev) > 4      # the fleet actually spreads out
        for recs in by_dev.values():
            spans = sorted((x.start, x.end) for x in recs)
            for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
                assert s2 >= e1 - 1e-9
        # EDF among queued jobs (same check as the legacy suite)
        recs = sorted(r.records, key=lambda x: x.start)
        for dev_recs in by_dev.values():
            dev_recs.sort(key=lambda x: x.start)
            for a, b in zip(dev_recs, dev_recs[1:]):
                if b.arrival <= a.start:
                    assert a.deadline <= b.deadline + 1e-9
        assert set(engine.device_clocks) == set(range(8))
        assert all(c is not None for c in engine.device_clocks.values())

    def test_hooks_fire_per_event(self, testbed, fitted, app_feats):
        jobs = make_workload(APPS, testbed, seed=0)
        events = {"admit": 0, "dispatch": 0, "complete": 0}
        hooks = EngineHooks(
            on_admit=lambda j, t: events.__setitem__(
                "admit", events["admit"] + 1),
            on_dispatch=lambda j, d, c, s: events.__setitem__(
                "dispatch", events["dispatch"] + 1),
            on_complete=lambda r: events.__setitem__(
                "complete", events["complete"] + 1),
        )
        r = run_schedule(jobs, "min-energy", Testbed(seed=100),
                         predictor=fitted, app_features=app_feats,
                         hooks=hooks)
        n = len(r.records)
        assert events == {"admit": n, "dispatch": n, "complete": n}

    def test_unknown_policy_raises(self, testbed):
        with pytest.raises(ValueError, match="unknown policy"):
            run_schedule([], "warp-speed", testbed)

    def test_predictive_policy_needs_predictor(self, testbed):
        with pytest.raises(ValueError, match="needs a fitted predictor"):
            run_schedule([], "d-dvfs", testbed)

    def test_registry_matches_scheduler_tuple(self):
        assert POLICY_TUPLE == POLICY_NAMES == tuple(POLICIES)
        for name in POLICY_NAMES:
            assert resolve_policy(name, V5E_DVFS).name == name


# ---------------------------------------------------------------------- #
#  Budget managers
# ---------------------------------------------------------------------- #
class TestQueueAwareBudget:
    def test_duplicate_job_objects(self, testbed, fitted, app_feats):
        """The same Job object admitted twice (replayed workload) must not
        corrupt the incremental EDF list — results still match legacy."""
        jobs = make_workload(APPS[:4], testbed, seed=0)
        doubled = jobs + jobs              # same objects, twice
        kw = dict(predictor=fitted, app_features=app_feats)
        a = legacy_run_schedule(doubled, "d-dvfs", Testbed(seed=100), **kw)
        b = run_schedule(doubled, "d-dvfs", Testbed(seed=100), **kw)
        _assert_identical(a, b)

    def test_incremental_matches_bruteforce(self, testbed):
        """Random admit/pop interleavings: the incremental EDF list computes
        the same cap as re-sorting the queue (the legacy algorithm)."""
        rng = np.random.default_rng(0)
        jobs = list(stream_workload(APPS, testbed, n_jobs=40, seed=7))
        tmin = {j.name: testbed.true_time(j.app, V5E_DVFS.max_clock)
                for j in jobs}
        mgr = QueueAwareBudget(lambda j: tmin[j.name])
        mgr.reset()
        queued, counter = [], 0
        for j in jobs:
            mgr.on_admit(j)
            queued.append((j.deadline, counter, j))
            counter += 1
            if queued and rng.random() < 0.4:
                k = int(rng.integers(len(queued)))
                dl, c, popped = queued.pop(k)
                mgr.on_pop(popped)
            start = float(rng.uniform(0, 100))
            budget0 = float(rng.uniform(10, 200))
            got = mgr.apply(j, start, budget0)
            want, cum = budget0, 0.0
            for dl_j, _, job_j in sorted(queued):
                cum += tmin[job_j.name]
                want = min(want, dl_j - start - cum)
            assert got == pytest.approx(want, abs=1e-12)
