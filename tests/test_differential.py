"""Differential / property harness for the preemptive engine (PR 5).

Two complementary nets over the segmented dispatch loop:

* **Differential identity** — hypothesis-generated random (pool, workload,
  policy, cap, quantum) configurations, run through the *segmented* engine
  with a trigger-disabled :class:`~repro.core.preemption.PreemptionManager`
  (boundaries are visited, every verdict declines) and through the plain
  engine: the record streams must be **bit-identical**. This is the
  strongest statement that segmentation itself is free — admissions,
  budgets, feedback delivery, cap grants, and the RNG stream all line up.
* **Conservation properties** — with triggers armed on the rescue-stress
  stream: work is never lost or double-run (Σ segment ``work_frac`` per
  job is exactly 1, segments contiguous with exactly one final record),
  billed energy decomposes exactly into duration x draw + explicit
  overhead joules, and per-device segments never overlap across
  preemption events.

Plus the satellite coverage this PR hardens:

* ``BudgetManager.snapshot/restore`` under repeated deferral+preemption
  interleavings (rollback round-trips compose — the capped engine's
  deferral path and the preemptive remnant re-admissions exercise the
  same contract);
* :class:`~repro.core.powercap.PowerTelemetry` ledgers over schedules
  containing *split* busy intervals from preempted segments (integrals
  stay exact, steps stay nonnegative, grants stay under the cap).

Runs with or without the real ``hypothesis`` package — the deterministic
shim in ``_hypothesis_fallback`` honors the ``@settings`` kwargs and
strategies used here, so the suite collects identically either way.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed in this container — deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.paper_suite import PAPER_APPS
from repro.core import (
    BATCH_TIER, BEST_EFFORT_TIER, DEFAULT_TIER, EnergyTimePredictor,
    FacilityCoordinator, FederatedPreemptionManager, Job,
    PowerCapCoordinator, PowerTelemetry, PredictorConfig, PreemptionConfig,
    PreemptionManager, SLO_TIER, Testbed, V5E_CLASS, V5E_DVFS, V5LITE_CLASS,
    V5P_CLASS, build_dataset, edf_key, merge_workloads, model_app_suite,
    multi_rack_workload, profile_features, register_model_apps,
    rescue_stress_workload, run_schedule, serving_workload, stream_workload,
    training_workload,
)
from repro.core.gbdt import GBDTParams
from repro.core.policies import (MinEnergy, POLICY_NAMES, QueueAwareBudget,
                                 VirtualPacingBudget)
from repro.core.prediction_service import ClockTable

APPS = list(PAPER_APPS)[:6]
SMALL = PredictorConfig(
    gbdt=GBDTParams(iterations=60, depth=3, learning_rate=0.15,
                    l2_leaf_reg=5.0),
    gbdt_time=GBDTParams(iterations=60, depth=3, learning_rate=0.15,
                         l2_leaf_reg=3.0),
)

#: Pool shapes the differential sweep draws from: classless single/multi
#: device, a uniform explicit pool, and a mixed pool (joint placement).
_POOLS: tuple = (
    ("classless-1", None, 1),
    ("classless-2", None, 2),
    ("uniform-v5e", [V5E_CLASS] * 3, 3),
    ("mixed", [V5P_CLASS, V5E_CLASS, V5LITE_CLASS], 3),
)
#: Cap regimes: uncoordinated, coordinated-but-infinite, binding.
_CAPS = ("none", "inf", "binding")

#: Trigger-disabled config: boundaries are visited, verdicts all decline.
_OFF = PreemptionConfig(self_rescue=False, queue_rescue=False)
#: Armed config, tuned eager so conservation tests see real preemptions.
_ARMED = PreemptionConfig(margin=0.02, min_remnant_frac=0.02)


@functools.lru_cache(maxsize=1)
def _fixture():
    tb = Testbed(seed=0)
    X, yp, yt, _ = build_dataset(APPS, tb, seed=0)
    rng = np.random.default_rng(7)
    return {
        "testbed": tb,
        "predictor": EnergyTimePredictor(SMALL).fit(X, yp, yt),
        "features": {a.name: profile_features(a, tb, rng=rng)
                     for a in APPS},
    }


def _jobs(seed: int, pool_idx: int, quantum: float) -> list[Job]:
    """A quantum-carrying job list: the Poisson stream with every job made
    interruptible (quantum scaled off its own DC slack)."""
    f = _fixture()
    _, _, n_dev = _POOLS[pool_idx]
    jobs = list(stream_workload(APPS, f["testbed"], n_jobs=30, seed=seed,
                                n_devices=n_dev))
    return [dataclasses.replace(j, checkpoint_quantum=quantum)
            for j in jobs]


#: SLA tiers the multi-tenant fuzz assigns at random (PR 7) — includes
#: the default tier so runs mix tagged and untagged work.
_TIER_CHOICES = (SLO_TIER, BATCH_TIER, BEST_EFFORT_TIER, DEFAULT_TIER)


def _tiered(jobs: list[Job], tier_seed: int) -> list[Job]:
    """Deterministic random tier assignment over an existing stream."""
    rng = np.random.default_rng(tier_seed)
    picks = rng.integers(0, len(_TIER_CHOICES), size=len(jobs))
    return [dataclasses.replace(j, tier=_TIER_CHOICES[int(k)])
            for j, k in zip(jobs, picks)]


def _coordinator(cap_kind: str, jobs, pool_idx: int, policy: str):
    """None, an infinite coordinator, or one binding at 60% of this
    configuration's uncapped peak headroom."""
    if cap_kind == "none":
        return None
    if cap_kind == "inf":
        return PowerCapCoordinator(math.inf, guard=0.15)
    f = _fixture()
    name, pool, n_dev = _POOLS[pool_idx]
    r0 = _run(jobs, pool_idx, policy, coordinator=None, preemption=None)
    if pool is not None:
        led = PowerTelemetry.from_result(r0, pool=pool)
        idle = sum(c.idle_power() for c in pool)
    else:
        idle_w = f["testbed"].idle_power()
        led = PowerTelemetry.from_result(r0, idle_powers=idle_w,
                                         n_devices=n_dev)
        idle = idle_w * n_dev
    cap = idle + 0.6 * max(led.peak_w - idle, 1.0)
    return PowerCapCoordinator(cap, grant_policy="slack-weighted",
                               guard=0.15)


def _run(jobs, pool_idx: int, policy: str, coordinator, preemption):
    f = _fixture()
    _, pool, n_dev = _POOLS[pool_idx]
    return run_schedule(
        jobs, policy, Testbed(seed=1000),
        predictor=f["predictor"], app_features=f["features"],
        n_devices=n_dev, device_classes=pool,
        power_coordinator=coordinator, preemption=preemption)


def _assert_identical(a, b):
    assert len(a.records) == len(b.records)
    for i, (ra, rb) in enumerate(zip(a.records, b.records)):
        assert ra == rb, (i, ra, rb)


# ---------------------------------------------------------------------- #
#  Differential identity: segmented-but-never-preempted == plain engine
# ---------------------------------------------------------------------- #
class TestDifferentialIdentity:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 50),
           pool_idx=st.integers(0, len(_POOLS) - 1),
           policy=st.sampled_from(list(POLICY_NAMES)),
           quantum=st.floats(0.05, 2.0))
    def test_segmented_never_preempted_is_bit_identical(
            self, seed, pool_idx, policy, quantum):
        """Random (seed, pool, policy, quantum): a trigger-disabled
        manager visits every boundary yet reproduces the plain engine's
        records bit-for-bit (compare= fields included)."""
        jobs = _jobs(seed, pool_idx, quantum)
        a = _run(jobs, pool_idx, policy, None, None)
        mgr = PreemptionManager(_OFF)
        b = _run(jobs, pool_idx, policy, None, mgr)
        _assert_identical(a, b)
        assert mgr.stats.preemptions == 0
        assert all(r.segment == 0 and not r.preempted for r in b.records)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 50),
           pool_idx=st.integers(0, len(_POOLS) - 1),
           policy=st.sampled_from(list(POLICY_NAMES)),
           quantum=st.floats(0.05, 2.0),
           tier_seed=st.integers(0, 1000))
    def test_tiered_segmented_never_preempted_is_bit_identical(
            self, seed, pool_idx, policy, quantum, tier_seed):
        """PR 7: the same identity with random SLA tiers on every job —
        tier-priority queue keys and tier-weighted urgencies reorder
        work, but a preemption-disabled multi-tenant run must still be
        bit-identical to the plain (manager-less) engine on the same
        tiered stream, and no tier rescue may fire."""
        jobs = _tiered(_jobs(seed, pool_idx, quantum), tier_seed)
        a = _run(jobs, pool_idx, policy, None, None)
        mgr = PreemptionManager(_OFF)
        b = _run(jobs, pool_idx, policy, None, mgr)
        _assert_identical(a, b)
        assert mgr.stats.preemptions == 0
        assert mgr.stats.tier_rescues == 0
        assert all(r.segment == 0 and not r.preempted for r in b.records)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 50),
           pool_idx=st.integers(0, len(_POOLS) - 1),
           policy=st.sampled_from(["min-energy", "d-dvfs", "dc"]),
           cap_kind=st.sampled_from(list(_CAPS)),
           quantum=st.floats(0.05, 1.5))
    def test_identity_holds_under_power_caps(
            self, seed, pool_idx, policy, cap_kind, quantum):
        """The same identity through the coordinated paths: offers,
        ladder filtering, escalation, and deferral all happen at the same
        decisions with the same grants."""
        jobs = _jobs(seed, pool_idx, quantum)
        coord_a = _coordinator(cap_kind, jobs, pool_idx, policy)
        coord_b = _coordinator(cap_kind, jobs, pool_idx, policy)
        a = _run(jobs, pool_idx, policy, coord_a, None)
        b = _run(jobs, pool_idx, policy, coord_b, PreemptionManager(_OFF))
        _assert_identical(a, b)

    @pytest.mark.parametrize("policy", POLICY_NAMES)
    @pytest.mark.parametrize("pool_idx", range(len(_POOLS)),
                             ids=[p[0] for p in _POOLS])
    def test_exhaustive_all_policies_all_pools(self, policy, pool_idx):
        """The acceptance grid, exhaustively (not sampled): every policy
        × classless / uniform / mixed pools, uncapped and under a
        binding cap, with a segmented-but-never-preempting manager —
        records bit-identical to the plain engine."""
        jobs = _jobs(3, pool_idx, 0.3)
        for cap_kind in ("none", "binding"):
            coord_a = _coordinator(cap_kind, jobs, pool_idx, policy)
            coord_b = _coordinator(cap_kind, jobs, pool_idx, policy)
            a = _run(jobs, pool_idx, policy, coord_a, None)
            b = _run(jobs, pool_idx, policy, coord_b,
                     PreemptionManager(_OFF))
            _assert_identical(a, b)

    def test_boundaries_are_actually_visited(self):
        """The identity above must not be vacuous: on a stream of
        interruptible jobs the disabled manager really does visit
        segment boundaries (and declines every one)."""
        jobs = _jobs(0, 0, 0.1)
        mgr = PreemptionManager(_OFF)
        _run(jobs, 0, "min-energy", None, mgr)
        assert mgr.stats.boundaries > 0
        assert mgr.stats.preemptions == 0

    @pytest.mark.parametrize("pool_idx", [0, 1, 3],
                             ids=[_POOLS[i][0] for i in (0, 1, 3)])
    def test_identity_with_feedback_attached(self, pool_idx):
        """The segmented loop's deferred feedback delivery (fb_seq
        assigned at dispatch, records finalized at completion or by an
        early drain) must hand the OnlineAdapter the same observation
        stream as the plain loop — corrected tables, and therefore every
        decision, stay bit-identical when no boundary fires."""
        from repro.core import OnlineAdapter, PredictionService
        f = _fixture()
        _, pool, n_dev = _POOLS[pool_idx]
        jobs = _jobs(2, pool_idx, 0.2)
        results = []
        for mgr in (None, PreemptionManager(_OFF)):
            svc = PredictionService(V5E_DVFS, predictor=f["predictor"],
                                    app_features=f["features"],
                                    testbed=f["testbed"])
            adapter = OnlineAdapter(svc)
            results.append((run_schedule(
                jobs, "min-energy", Testbed(seed=1000), service=svc,
                n_devices=n_dev, device_classes=pool, feedback=adapter,
                preemption=mgr), adapter))
        (a, ad_a), (b, ad_b) = results
        _assert_identical(a, b)
        assert ad_a.n_observed == ad_b.n_observed == len(a.records)

    def test_feedback_observes_per_segment(self):
        """With rescues armed and an adapter attached, every segment is
        a feedback observation (the per-segment residual normalization
        path) — preemptions don't starve the measurement loop."""
        from repro.core import OnlineAdapter, PredictionService
        f = _fixture()
        jobs = list(rescue_stress_workload(APPS, f["testbed"], n_jobs=36,
                                           seed=0, n_devices=1))
        svc = PredictionService(V5E_DVFS, predictor=f["predictor"],
                                app_features=f["features"],
                                testbed=f["testbed"])
        adapter = OnlineAdapter(svc)
        r = run_schedule(jobs, "min-energy", Testbed(seed=1000),
                         service=svc, feedback=adapter,
                         preemption=PreemptionManager(_ARMED))
        assert r.preemptions > 0
        # every segment with real execution time is observed (truncated
        # checkpoint-only slivers may be skipped — count those out)
        slivers = sum(1 for x in r.records
                      if x.work_frac <= 1e-9
                      or x.time_s - x.overhead_s <= 0)
        assert adapter.n_observed == len(r.records) - slivers
        assert adapter.n_observed > len(jobs)     # segments > jobs


# ---------------------------------------------------------------------- #
#  Conservation: work and energy, with triggers armed
# ---------------------------------------------------------------------- #
def _preemptive_run(seed: int, n_devices: int, cap_kind: str = "none"):
    f = _fixture()
    jobs = list(rescue_stress_workload(APPS, f["testbed"], n_jobs=36,
                                       seed=seed, n_devices=n_devices))
    coord = None
    if cap_kind == "binding":
        r0 = run_schedule(jobs, "min-energy", Testbed(seed=1000),
                          predictor=f["predictor"],
                          app_features=f["features"], n_devices=n_devices)
        idle = f["testbed"].idle_power() * n_devices
        led = PowerTelemetry.from_result(
            r0, idle_powers=f["testbed"].idle_power(),
            n_devices=n_devices)
        coord = PowerCapCoordinator(
            idle + 0.65 * max(led.peak_w - idle, 1.0), guard=0.15)
    mgr = PreemptionManager(_ARMED)
    r = run_schedule(jobs, "min-energy", Testbed(seed=1000),
                     predictor=f["predictor"], app_features=f["features"],
                     n_devices=n_devices, power_coordinator=coord,
                     preemption=mgr)
    return jobs, r, mgr, coord


class TestConservation:
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 20), n_devices=st.integers(1, 3))
    def test_work_never_lost_or_double_run(self, seed, n_devices):
        jobs, r, mgr, _ = self._checked(seed, n_devices)
        by_job: dict[int, list] = {}
        for rec in r.records:
            by_job.setdefault(rec.job_id, []).append(rec)
        assert sorted(by_job) == sorted(j.job_id for j in jobs)
        for jid, recs in by_job.items():
            # Σ work_frac == 1: remnant work neither lost nor repeated
            assert math.fsum(x.work_frac for x in recs) == pytest.approx(
                1.0, abs=1e-9), jid
            # segments contiguous 0..k in start-time order, exactly one
            # final (non-preempted) record, and it is the last
            recs.sort(key=lambda x: x.start)
            assert [x.segment for x in recs] == list(range(len(recs)))
            assert [x.preempted for x in recs] == \
                [True] * (len(recs) - 1) + [False]

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 20), n_devices=st.integers(1, 3))
    def test_energy_decomposes_exactly(self, seed, n_devices):
        """Billed energy = duration x measured draw + explicit overhead
        joules, per record — so summed segment energies are the job's
        whole bill, checkpoint/restore included."""
        _, r, _, _ = self._checked(seed, n_devices)
        for rec in r.records:
            assert rec.energy_j == pytest.approx(
                rec.time_s * rec.power_w + rec.overhead_j, rel=1e-12)
            assert rec.time_s == pytest.approx(rec.end - rec.start,
                                               rel=1e-12)
            assert 0.0 <= rec.work_frac <= 1.0 + 1e-12

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 20), n_devices=st.integers(1, 3))
    def test_no_device_overlap_across_preemptions(self, seed, n_devices):
        _, r, _, _ = self._checked(seed, n_devices)
        by_dev: dict[int, list] = {}
        for rec in r.records:
            by_dev.setdefault(rec.device, []).append((rec.start, rec.end))
        for spans in by_dev.values():
            spans.sort()
            for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
                assert s2 >= e1 - 1e-9

    _cache: dict = {}

    def _checked(self, seed, n_devices):
        key = (seed, n_devices)
        if key not in self._cache:
            self._cache[key] = _preemptive_run(seed, n_devices)
        return self._cache[key]

    def test_preemptions_actually_happen(self):
        """The conservation net must not be vacuous."""
        fired = 0
        for seed in range(4):
            _, r, _, _ = self._checked(seed, 1)
            fired += r.preemptions
        assert fired > 0

    def test_misses_counted_per_job_not_per_segment(self):
        _, r, _, _ = self._checked(0, 1)
        finals = r.final_records()
        assert len(finals) == len({x.job_id for x in r.records})
        assert r.misses == sum(not x.met_deadline for x in finals)
        assert r.misses <= len(finals)


# ---------------------------------------------------------------------- #
#  Power cap x preemption: grants shrink at boundaries, ledger exact
# ---------------------------------------------------------------------- #
#  Federation (PR 9): cross-rack migration keeps conservation discipline
# ---------------------------------------------------------------------- #
def _federated_run(seed: int):
    """A 2x2-device federation with one injected slow device: binding
    facility cap, demand-weighted shares, straggler rescue armed."""
    f = _fixture()
    jobs = list(multi_rack_workload(APPS, f["testbed"], n_devices=4,
                                    n_jobs=40, seed=seed))
    r0 = run_schedule(jobs, "min-energy", Testbed(seed=1000),
                      predictor=f["predictor"], app_features=f["features"],
                      n_devices=4)
    idle = f["testbed"].idle_power() * 4
    led = PowerTelemetry.from_result(r0, idle_powers=f["testbed"].idle_power(),
                                     n_devices=4)
    fed = FacilityCoordinator(idle + 0.7 * max(led.peak_w - idle, 1.0),
                              [2, 2], share_policy="demand-weighted",
                              guard=0.15)
    pre = FederatedPreemptionManager([2, 2], config=_ARMED,
                                     dvfs=f["testbed"].dvfs,
                                     device_slowdown={1: 2.5})
    r = run_schedule(jobs, "min-energy", Testbed(seed=1000),
                     predictor=f["predictor"], app_features=f["features"],
                     n_devices=4, power_coordinator=fed, preemption=pre)
    return jobs, r, fed, pre


class TestFederatedMigration:
    _cache: dict = {}

    def _run(self, seed):
        if seed not in self._cache:
            self._cache[seed] = _federated_run(seed)
        return self._cache[seed]

    def test_conservation_spans_racks(self):
        """Σ work_frac == 1 per job even when its segments land on
        different racks; migrated segments are always remnants."""
        for seed in range(3):
            jobs, r, _, _ = self._run(seed)
            by_job: dict[int, list] = {}
            for rec in r.records:
                by_job.setdefault(rec.job_id, []).append(rec)
            assert sorted(by_job) == sorted(j.job_id for j in jobs)
            for jid, recs in by_job.items():
                assert math.fsum(x.work_frac for x in recs) == \
                    pytest.approx(1.0, abs=1e-9), (seed, jid)
            for rec in r.records:
                if rec.migrated:
                    assert rec.segment > 0
                    assert rec.rack is not None

    def test_migration_counters_consistent(self):
        """``migrations`` == migrated records == Σ per-rack counts, and
        each migrated segment really changed racks vs its predecessor."""
        total = 0
        for seed in range(3):
            _, r, _, _ = self._run(seed)
            migrated = [x for x in r.records if x.migrated]
            assert r.migrations == len(migrated)
            by_rack = r.migrations_by_rack()
            assert sum(by_rack.values()) == r.migrations
            prev_rack = {}
            for rec in sorted(r.records, key=lambda x: (x.job_id,
                                                        x.segment)):
                if rec.migrated:
                    assert prev_rack[rec.job_id] != rec.rack, rec
                    assert by_rack.get(rec.rack, 0) > 0
                prev_rack[rec.job_id] = rec.rack
            total += r.migrations
        assert total > 0  # the net is not vacuous

    def test_plain_runs_report_zero_migrations(self):
        """Non-federated schedules never invent migrations: counters are
        zero and the per-rack map is empty (rack provenance absent)."""
        _, r, _, _ = _preemptive_run(0, 2)
        assert r.migrations == 0
        assert r.migrations_by_rack() == {}
        assert all(x.rack is None for x in r.records)


# ---------------------------------------------------------------------- #
class TestCappedPreemption:
    def test_granted_ledger_stays_under_cap_with_preemption(self):
        """Preempted grants are truncated at the boundary; the
        granted-view ledger built from split records must still never sum
        above the cap, and the measured ledger's integral must stay
        exactly Σ busy + idle energy."""
        f = _fixture()
        for seed in range(3):
            _, r, _, coord = _preemptive_run(seed, 2, cap_kind="binding")
            idle_w = f["testbed"].idle_power()
            for view in ("measured", "granted"):
                led = PowerTelemetry.from_result(
                    r, idle_powers=idle_w, n_devices=2, view=view)
                assert led.peak_w <= coord.cap_w + 1e-6, (seed, view)

    def test_split_interval_ledger_integral_exact(self):
        """Telemetry over a schedule with preempted (split) busy
        intervals: the step function integrates exactly to Σ record
        draw x duration + idle energy — no discretization error from the
        extra breakpoints, and every step nonnegative."""
        f = _fixture()
        _, r, _, _ = self._split_run()
        idle_w = f["testbed"].idle_power()
        n_dev = 2
        led = PowerTelemetry.from_result(r, idle_powers=idle_w,
                                         n_devices=n_dev)
        horizon = max(x.end for x in r.records)
        busy = math.fsum(x.power_w * (x.end - x.start) for x in r.records)
        busy_t = math.fsum(x.end - x.start for x in r.records)
        idle_e = idle_w * (n_dev * horizon - busy_t)
        assert led.energy_j() == pytest.approx(busy + idle_e, rel=1e-9)
        assert all(s.watts >= 0.0 for s in led.segments)
        # truncated horizon still exact (clipped busy + clipped idle)
        h2 = horizon * 0.5
        led2 = PowerTelemetry.from_result(r, idle_powers=idle_w,
                                          n_devices=n_dev, horizon=h2)
        busy2 = busy_t2 = 0.0
        for x in r.records:
            lo, hi = max(x.start, 0.0), min(x.end, h2)
            if hi > lo:
                busy2 += x.power_w * (hi - lo)
                busy_t2 += hi - lo
        assert led2.energy_j() == pytest.approx(
            busy2 + idle_w * (n_dev * h2 - busy_t2), rel=1e-9)

    _split_cache: dict = {}        # class-level: shared across instances

    def _split_run(self):
        if "run" not in self._split_cache:
            jobs, r, mgr, coord = _preemptive_run(0, 2)
            assert r.preemptions > 0   # the net must cover split intervals
            self._split_cache["run"] = (jobs, r, mgr, coord)
        return self._split_cache["run"]


# ---------------------------------------------------------------------- #
#  Cold-start fuzz: mixed profiled/unseen app set (PR 8)
# ---------------------------------------------------------------------- #
def _mixed_jobs(seed: int, pool_idx: int, quantum: float) -> list[Job]:
    """A stream interleaving the profiled corpus with never-profiled
    variants (new names, divergent latents) the synthesizer must serve."""
    f = _fixture()
    _, _, n_dev = _POOLS[pool_idx]
    rng = np.random.default_rng(seed)
    novel = [dataclasses.replace(
        APPS[i % len(APPS)], name=f"novel-{i}", seed=700 + i,
        stall_frac=float(rng.uniform(0.2, 0.5)),
        core_eff=float(rng.uniform(0.55, 0.85)))
        for i in range(3)]
    jobs = list(stream_workload(APPS + novel, f["testbed"], n_jobs=30,
                                seed=seed, n_devices=n_dev))
    return [dataclasses.replace(j, checkpoint_quantum=quantum)
            for j in jobs]


def _cold_run(jobs, pool_idx: int, policy: str, coordinator, preemption):
    from repro.core import ColdStartSynthesizer
    f = _fixture()
    _, pool, n_dev = _POOLS[pool_idx]
    synth = ColdStartSynthesizer()
    r = run_schedule(
        jobs, policy, Testbed(seed=1000),
        predictor=f["predictor"], app_features=f["features"],
        n_devices=n_dev, device_classes=pool,
        power_coordinator=coordinator, preemption=preemption,
        coldstart=synth)
    return r, synth


def _cold_coordinator(cap_kind: str, jobs, pool_idx: int, policy: str):
    """Like _coordinator, but the headroom probe runs with a synthesizer
    attached (the mixed stream is unschedulable without one)."""
    if cap_kind == "none":
        return None
    if cap_kind == "inf":
        return PowerCapCoordinator(math.inf, guard=0.15)
    f = _fixture()
    _, pool, n_dev = _POOLS[pool_idx]
    r0, _ = _cold_run(jobs, pool_idx, policy, None, None)
    if pool is not None:
        led = PowerTelemetry.from_result(r0, pool=pool)
        idle = sum(c.idle_power() for c in pool)
    else:
        idle_w = f["testbed"].idle_power()
        led = PowerTelemetry.from_result(r0, idle_powers=idle_w,
                                         n_devices=n_dev)
        idle = idle_w * n_dev
    cap = idle + 0.6 * max(led.peak_w - idle, 1.0)
    return PowerCapCoordinator(cap, grant_policy="slack-weighted",
                               guard=0.15)


class TestColdStartMixedFuzz:
    """Random pool x policy x cap x preemption configurations on a mixed
    profiled/unseen stream: the engine must admit unknown apps through the
    synthesized tier and keep every structural invariant the profiled-only
    fuzz pins — overlap-free devices, EDF dispatch among admitted jobs,
    and exact energy/work conservation."""

    def _check_structure(self, jobs, r):
        # every job executes; per-job work sums to 1 with one final record
        by_job: dict[int, list] = {}
        for rec in r.records:
            by_job.setdefault(rec.job_id, []).append(rec)
        assert sorted(by_job) == sorted(j.job_id for j in jobs)
        for jid, recs in by_job.items():
            recs.sort(key=lambda x: x.start)
            assert math.fsum(x.work_frac for x in recs) == pytest.approx(
                1.0, abs=1e-9), jid
            assert [x.preempted for x in recs] == \
                [True] * (len(recs) - 1) + [False]
        # energy-conserving: billed energy decomposes exactly
        for rec in r.records:
            assert rec.energy_j == pytest.approx(
                rec.time_s * rec.power_w + rec.overhead_j, rel=1e-12)
        # overlap-free: per-device busy spans never intersect
        by_dev: dict[int, list] = {}
        for rec in r.records:
            by_dev.setdefault(rec.device, []).append((rec.start, rec.end))
        for spans in by_dev.values():
            spans.sort()
            for (_, e1), (s2, _) in zip(spans, spans[1:]):
                assert s2 >= e1 - 1e-9

    def _check_edf(self, jobs, r):
        """EDF-among-admitted: when job b started while job a was already
        pending (arrived, unstarted) with an earlier deadline, the engine
        would have dispatched a first — so no such pair may exist."""
        starts = {rec.job_id: rec.start for rec in r.records
                  if rec.segment == 0}
        by_id = {j.job_id: j for j in jobs}
        order = sorted(starts.items(), key=lambda kv: kv[1])
        for i, (jb, sb) in enumerate(order):
            for ja, sa in order[i + 1:]:
                a, b = by_id[ja], by_id[jb]
                if a.arrival <= sb and sa > sb:
                    assert a.deadline >= b.deadline - 1e-9, (ja, jb)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 50),
           pool_idx=st.integers(0, len(_POOLS) - 1),
           policy=st.sampled_from(list(POLICY_NAMES)))
    def test_uncapped_nonpreemptive_invariants(self, seed, pool_idx,
                                               policy):
        jobs = _mixed_jobs(seed, pool_idx, 0.0)
        r, synth = _cold_run(jobs, pool_idx, policy, None, None)
        assert synth.stats.registered == 3       # unseen apps really served
        assert {rec.name for rec in r.records} >= {
            f"novel-{i}" for i in range(3)}
        self._check_structure(jobs, r)
        self._check_edf(jobs, r)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 50),
           pool_idx=st.integers(0, len(_POOLS) - 1),
           policy=st.sampled_from(["min-energy", "d-dvfs", "risk-aware"]),
           cap_kind=st.sampled_from(list(_CAPS)),
           preempt=st.sampled_from([False, True]),
           quantum=st.floats(0.05, 1.5))
    def test_capped_preemptive_invariants(self, seed, pool_idx, policy,
                                          cap_kind, preempt, quantum):
        jobs = _mixed_jobs(seed, pool_idx, quantum)
        coord = _cold_coordinator(cap_kind, jobs, pool_idx, policy)
        mgr = PreemptionManager(_ARMED) if preempt else None
        r, synth = _cold_run(jobs, pool_idx, policy, coord, mgr)
        assert synth.stats.registered == 3
        self._check_structure(jobs, r)

    def test_identity_with_trigger_disabled_manager(self):
        """The PR 5 differential net extends to the cold tier: a mixed
        stream through the segmented-but-never-preempting engine is
        bit-identical to the plain engine, synthesizer attached both
        times."""
        jobs = _mixed_jobs(7, 1, 0.2)
        a, _ = _cold_run(jobs, 1, "min-energy", None, None)
        b, _ = _cold_run(jobs, 1, "min-energy", None,
                         PreemptionManager(_OFF))
        _assert_identical(a, b)


# ---------------------------------------------------------------------- #
#  Model-derived apps (PR 10): inert registration + mixed-stream fuzz
# ---------------------------------------------------------------------- #
@functools.lru_cache(maxsize=1)
def _model_fixture():
    """The PR 10 fixture: the paper fixture plus the model-derived suite's
    feature vectors (registered through the dedicated-RNG profiling path,
    so building this perturbs nothing the paper fixture computed)."""
    f = _fixture()
    suite = model_app_suite()
    model_feats = register_model_apps(None, f["testbed"])
    return {**f, "suite": suite,
            "features_all": {**f["features"], **model_feats}}


@functools.lru_cache(maxsize=16)
def _mixed_model_jobs(seed: int, pool_idx: int, quantum: float):
    """Paper stream + diurnal serving mix + background train jobs, merged
    in arrival order with contiguous ids."""
    f = _model_fixture()
    _, pool, n_dev = _POOLS[pool_idx]
    jobs = merge_workloads(
        stream_workload(APPS, f["testbed"], n_jobs=12, seed=seed,
                        n_devices=n_dev),
        serving_workload(f["suite"], f["testbed"], n_jobs=14, seed=seed + 1,
                         pool=pool, n_devices=n_dev),
        training_workload(f["suite"], f["testbed"], n_jobs=6, seed=seed + 2,
                          pool=pool, n_devices=n_dev))
    if quantum:
        jobs = [dataclasses.replace(j, checkpoint_quantum=quantum)
                for j in jobs]
    return jobs


def _model_run(jobs, pool_idx: int, policy: str, coordinator, preemption):
    f = _model_fixture()
    _, pool, n_dev = _POOLS[pool_idx]
    return run_schedule(
        jobs, policy, Testbed(seed=1000),
        predictor=f["predictor"], app_features=f["features_all"],
        n_devices=n_dev, device_classes=pool,
        power_coordinator=coordinator, preemption=preemption)


class TestModelAppRegistrationInert:
    """Satellite: registering the derived suite must be observationally
    inert — a paper-suite-only run is bit-identical whether or not
    `model_apps` features sit in the service (invariant 12)."""

    def test_paper_only_bit_identical_all_policies(self):
        """Exhaustive over all six policies on the mixed-class pool:
        same jobs, same testbed seed, records bit-identical with the
        model-derived features merely registered."""
        f = _model_fixture()
        jobs = _jobs(3, 3, 0.0)
        for policy in POLICY_NAMES:
            a = _run(jobs, 3, policy, None, None)
            b = _model_run(jobs, 3, policy, None, None)
            _assert_identical(a, b)

    def test_paper_only_identical_capped_and_segmented(self):
        """The same inertness through the coordinated + segmented paths
        (binding cap, trigger-disabled manager): grants, deferrals, and
        boundary visits all line up."""
        jobs = _jobs(5, 1, 0.3)
        for cap_kind in ("none", "binding"):
            coord_a = _coordinator(cap_kind, jobs, 1, "min-energy")
            coord_b = _coordinator(cap_kind, jobs, 1, "min-energy")
            a = _run(jobs, 1, "min-energy", coord_a, None)
            b = _model_run(jobs, 1, "min-energy", coord_b,
                           PreemptionManager(_OFF))
            _assert_identical(a, b)

    def test_registration_preserves_rng_and_features(self):
        """Building the model fixture never mutates the paper fixture's
        feature dict or the shared testbed RNG state (the engine's
        determinism backbone)."""
        f0 = _fixture()
        state = f0["testbed"]._rng.bit_generator.state
        fm = _model_fixture()
        assert f0["testbed"]._rng.bit_generator.state == state
        assert set(f0["features"]) < set(fm["features_all"])
        for name in f0["features"]:
            assert fm["features_all"][name] is f0["features"][name]


class TestMixedModelStreamFuzz:
    """Satellite: paper + serving + training job mixes keep every
    structural invariant the profiled-only fuzz pins — uncapped, capped,
    and preemptive — with tier-aware EDF dispatch among admitted jobs."""

    def _check_edf_tiered(self, jobs, r):
        """EDF-among-admitted, generalized to SLA tiers: if job b started
        while a higher-urgency job a (by ``edf_key``: tier priority, then
        deadline) was already pending, the engine would have dispatched a
        first — so no such pair may exist."""
        starts = {rec.job_id: rec.start for rec in r.records
                  if rec.segment == 0}
        by_id = {j.job_id: j for j in jobs}
        order = sorted(starts.items(), key=lambda kv: kv[1])
        for i, (jb, sb) in enumerate(order):
            for ja, sa in order[i + 1:]:
                a, b = by_id[ja], by_id[jb]
                if a.arrival <= sb and sa > sb:
                    ka, kb = edf_key(a), edf_key(b)
                    assert (ka[0] > kb[0]
                            or (ka[0] == kb[0] and ka[1] >= kb[1] - 1e-9)), \
                        (ja, jb)

    def test_mixed_stream_is_not_vacuous(self):
        """The merged stream really schedules all three populations: at
        least one decode segment, one train step, multiple architectures,
        and at least one paper app are dispatched."""
        jobs = _mixed_model_jobs(0, 3, 0.0)
        r = _model_run(jobs, 3, "min-energy", None, None)
        names = {rec.name for rec in r.records}
        assert any(n.endswith(":decode") for n in names)
        assert any(n.endswith(":train_step") for n in names)
        assert len({n.split(":")[0] for n in names if ":" in n}) >= 2
        assert names & {a.name for a in APPS}

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 50),
           pool_idx=st.integers(0, len(_POOLS) - 1),
           policy=st.sampled_from(list(POLICY_NAMES)))
    def test_uncapped_nonpreemptive_invariants(self, seed, pool_idx,
                                               policy):
        jobs = _mixed_model_jobs(seed, pool_idx, 0.0)
        r = _model_run(jobs, pool_idx, policy, None, None)
        TestColdStartMixedFuzz._check_structure(self, jobs, r)
        self._check_edf_tiered(jobs, r)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 50),
           pool_idx=st.integers(0, len(_POOLS) - 1),
           policy=st.sampled_from(["min-energy", "d-dvfs", "risk-aware"]),
           cap_kind=st.sampled_from(list(_CAPS)),
           preempt=st.sampled_from([False, True]),
           quantum=st.floats(0.05, 1.5))
    def test_capped_preemptive_invariants(self, seed, pool_idx, policy,
                                          cap_kind, preempt, quantum):
        jobs = _mixed_model_jobs(seed, pool_idx, quantum)
        if cap_kind == "none":
            coord = None
        elif cap_kind == "inf":
            coord = PowerCapCoordinator(math.inf, guard=0.15)
        else:
            f = _model_fixture()
            _, pool, n_dev = _POOLS[pool_idx]
            r0 = _model_run(jobs, pool_idx, policy, None, None)
            if pool is not None:
                led = PowerTelemetry.from_result(r0, pool=pool)
                idle = sum(c.idle_power() for c in pool)
            else:
                idle_w = f["testbed"].idle_power()
                led = PowerTelemetry.from_result(r0, idle_powers=idle_w,
                                                 n_devices=n_dev)
                idle = idle_w * n_dev
            coord = PowerCapCoordinator(
                idle + 0.6 * max(led.peak_w - idle, 1.0),
                grant_policy="slack-weighted", guard=0.15)
        mgr = PreemptionManager(_ARMED) if preempt else None
        r = _model_run(jobs, pool_idx, policy, coord, mgr)
        TestColdStartMixedFuzz._check_structure(self, jobs, r)

    def test_segmented_never_preempted_identity_on_mixed_stream(self):
        """The PR 5 differential identity extends to the model-derived
        mix: trigger-disabled segmentation reproduces the plain engine
        bit-for-bit on a paper+serving+training stream."""
        jobs = _mixed_model_jobs(7, 3, 0.2)
        a = _model_run(jobs, 3, "min-energy", None, None)
        mgr = PreemptionManager(_OFF)
        b = _model_run(jobs, 3, "min-energy", None, mgr)
        _assert_identical(a, b)
        assert mgr.stats.preemptions == 0


# ---------------------------------------------------------------------- #
#  BudgetManager.snapshot/restore: rollbacks compose under interleavings
# ---------------------------------------------------------------------- #
class TestBudgetRollback:
    def _tmin(self):
        tb = _fixture()["testbed"]
        return {a.name: tb.true_time(a, V5E_DVFS.max_clock) for a in APPS}

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 100), defer_p=st.floats(0.1, 0.6))
    def test_queue_aware_rollbacks_compose(self, seed, defer_p):
        """Random admit / dispatch / deferral(snapshot-pop-apply-restore)
        interleavings — including remnant-style re-admissions right after
        a rollback: the manager's budget always equals the brute-force
        recomputation over the jobs *actually* queued, i.e. every
        rollback restored exactly the popped decision and nothing else,
        no matter how many compose."""
        rng = np.random.default_rng(seed)
        tmin = self._tmin()
        tb = _fixture()["testbed"]
        jobs = list(stream_workload(APPS, tb, n_jobs=30, seed=seed))
        mgr = QueueAwareBudget(lambda j: tmin[j.name])
        mgr.reset()
        queued: list[tuple[float, int, Job]] = []
        counter = 0

        def check(job):
            start = float(rng.uniform(0, 100))
            b0 = float(rng.uniform(10, 200))
            got = mgr.apply(job, start, b0)
            want, cum = b0, 0.0
            for dl_j, _, job_j in sorted(queued):
                cum += tmin[job_j.name]
                want = min(want, dl_j - start - cum)
            assert got == pytest.approx(want, abs=1e-12)

        for j in jobs:
            mgr.on_admit(j)
            queued.append((j.deadline, counter, j))
            counter += 1
            r = rng.random()
            if queued and r < defer_p:
                # deferral: snapshot → pop → apply → restore (the capped
                # engine's rollback path), sometimes twice in a row —
                # with admissions continuing between episodes, exactly
                # the remnant-re-admission interleaving the preemptive
                # loop produces
                for _ in range(1 + int(rng.random() < 0.3)):
                    k = int(rng.integers(len(queued)))
                    _, _, victim = queued[k]
                    snap = mgr.snapshot()
                    mgr.on_pop(victim)
                    mgr.apply(victim, float(rng.uniform(0, 50)), 100.0)
                    mgr.restore(snap)
                    check(victim)
            elif queued and r < defer_p + 0.3:
                k = int(rng.integers(len(queued)))
                _, _, popped = queued.pop(k)
                mgr.on_pop(popped)          # a real dispatch: no rollback
            check(j)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 100))
    def test_virtual_pacing_rollbacks_compose(self, seed):
        rng = np.random.default_rng(seed)
        tb = _fixture()["testbed"]
        jobs = list(stream_workload(APPS, tb, n_jobs=20, seed=seed))
        t_dc = {a.name: tb.true_time(a, V5E_DVFS.default_clock)
                for a in APPS}
        mgr = VirtualPacingBudget(lambda j: t_dc[j.name])
        mgr.reset()
        shadow = VirtualPacingBudget(lambda j: t_dc[j.name])
        shadow.reset()
        for j in jobs:
            start = float(rng.uniform(0, 200))
            if rng.random() < 0.5:
                # deferred decision (possibly nested twice): net no-op
                for _ in range(1 + int(rng.random() < 0.4)):
                    snap = mgr.snapshot()
                    mgr.apply(j, start, 100.0)
                    mgr.restore(snap)
            got = mgr.apply(j, start, 100.0)
            want = shadow.apply(j, start, 100.0)
            assert got == pytest.approx(want, abs=1e-12)
            assert mgr.snapshot() == shadow.snapshot()


# ---------------------------------------------------------------------- #
#  Rescue-decision units (PreemptionManager.decide, branch by branch)
# ---------------------------------------------------------------------- #
class TestRescueDecision:
    """Drive decide() against a fabricated engine/segment so every
    verdict branch — including the watt-limited cap-rescue labeling the
    integration streams rarely reach — is pinned directly."""

    def _setup(self, *, committed_T=20.0, fast_T=2.0, fast_P=200.0,
               grant=None, potential=math.inf, deadline=10.0,
               remaining=0.5):
        import types
        clocks = (V5E_DVFS.min_clock, V5E_DVFS.max_clock)
        tab = ClockTable(clocks=clocks,
                         P=np.array([50.0, fast_P]),
                         T=np.array([committed_T, fast_T]))
        coord = types.SimpleNamespace(
            guard=0.0, potential_w=lambda dev: potential)
        engine = types.SimpleNamespace(
            _table_for=lambda job, cls: tab,
            _t_min_est=lambda job, cls: None,
            policy=MinEnergy(V5E_DVFS),
            power_coordinator=coord if grant is not None else None,
            n_devices=1)
        job = Job(app=APPS[0], arrival=0.0, deadline=deadline, job_id=0,
                  checkpoint_quantum=0.5)
        seg = types.SimpleNamespace(
            job=job, dev=0, device_class=None, class_key=None,
            clock=clocks[0], grant=grant, done=False, end=100.0,
            remaining_at=lambda t: remaining)
        return engine, seg

    def test_self_rescue_fires_on_predicted_miss(self):
        engine, seg = self._setup()
        mgr = PreemptionManager(PreemptionConfig())
        # committed: 0.5 x 20 = 10s remaining from t=1 -> misses t=10;
        # the fast clock (0.5 x 2 + overheads) saves it
        assert mgr.decide(engine, seg, 1.0, [], {}) == "self-rescue"
        assert mgr.stats.self_rescues == 1

    def test_cap_rescue_labels_watt_limited_rescue(self):
        # same geometry, but the running grant (60 W) blocks the 200 W
        # fast clock while the coordinator's reclaim bound covers it:
        # the rescue is real and must be labeled cap-rescue
        engine, seg = self._setup(grant=60.0, potential=500.0)
        mgr = PreemptionManager(PreemptionConfig())
        assert mgr.decide(engine, seg, 1.0, [], {}) == "cap-rescue"
        assert mgr.stats.cap_rescues == 1
        assert mgr.stats.self_rescues == 0

    def test_rescue_declined_when_no_watts_reclaimable(self):
        # the fast clock exceeds even the reclaim bound: preempting buys
        # nothing, the boundary must decline
        engine, seg = self._setup(grant=60.0, potential=100.0)
        mgr = PreemptionManager(PreemptionConfig())
        assert mgr.decide(engine, seg, 1.0, [], {}) is None
        assert mgr.stats.declined == 1

    def test_rescue_declined_when_doomed(self):
        # even the fastest clock cannot make the deadline: decline (the
        # sprint-on-miss burn stays where it is, no checkpoint waste)
        engine, seg = self._setup(fast_T=30.0)
        mgr = PreemptionManager(PreemptionConfig())
        assert mgr.decide(engine, seg, 1.0, [], {}) is None

    def test_rescue_declined_when_healthy(self):
        engine, seg = self._setup(committed_T=4.0, deadline=50.0)
        mgr = PreemptionManager(PreemptionConfig())
        assert mgr.decide(engine, seg, 1.0, [], {}) is None

    def test_nearly_done_jobs_never_preempted(self):
        engine, seg = self._setup(remaining=0.01)
        mgr = PreemptionManager(PreemptionConfig())
        assert mgr.decide(engine, seg, 1.0, [], {}) is None
        assert mgr.stats.checks == 0       # below min_remnant_frac

    def test_max_preemptions_bounds_remnant_storms(self):
        engine, seg = self._setup()
        seg.job = dataclasses.replace(seg.job, segment=8)
        mgr = PreemptionManager(PreemptionConfig(max_preemptions=8))
        assert mgr.decide(engine, seg, 1.0, [], {}) is None


# ---------------------------------------------------------------------- #
#  Policy-level remnant units
# ---------------------------------------------------------------------- #
class TestResumeSelection:
    def _table(self):
        clocks = tuple(V5E_DVFS.clock_list())
        T = np.linspace(40.0, 8.0, len(clocks))
        P = np.linspace(60.0, 220.0, len(clocks))
        return ClockTable(clocks=clocks, P=P, T=T)

    def test_select_resume_scales_remaining_work(self):
        pol = MinEnergy(V5E_DVFS)
        tab = self._table()
        job = Job(app=APPS[0], arrival=0.0, deadline=100.0, job_id=0)
        # whole job: nothing feasible within 10 s except the fast end
        whole = pol.select_clock(job, 10.0, tab)
        # half the work + 0.5 s restore: slower, cheaper clocks open up
        half = pol.select_resume(job, 10.0, tab, work_frac=0.5,
                                 overhead_s=0.5)
        assert whole.feasible and half.feasible
        assert half.time <= whole.time     # scaled table times
        i_whole = tab.clocks.index(whole.clock)
        i_half = tab.clocks.index(half.clock)
        assert i_half <= i_whole           # never a faster clock needed
        # the scaled prediction is exactly work_frac * T + overhead
        assert half.time == pytest.approx(
            0.5 * tab.T[i_half] + 0.5, rel=1e-12)

    def test_rescue_trigger_margins(self):
        pol = MinEnergy(V5E_DVFS)
        assert pol.rescue_trigger(10.0, 15.0, 6.0)          # 16 > 15
        assert not pol.rescue_trigger(10.0, 15.0, 4.0)      # 14 < 15
        # margin inflates the estimate: 4.8 -> 14.8 still fine, 5 x 1.2
        # -> 16 trips
        assert not pol.rescue_trigger(10.0, 15.0, 4.0, margin=0.2)
        assert pol.rescue_trigger(10.0, 15.0, 5.0, margin=0.2)

    def test_select_resume_whole_job_is_plain_selection(self):
        pol = MinEnergy(V5E_DVFS)
        tab = self._table()
        job = Job(app=APPS[0], arrival=0.0, deadline=100.0, job_id=0)
        a = pol.select_clock(job, 30.0, tab)
        b = pol.select_resume(job, 30.0, tab, work_frac=1.0,
                              overhead_s=0.0)
        assert a == b

    def test_select_resume_matches_engine_remnant_lens(self):
        """select_resume (the policy-level API) and the engine's actual
        resume path (remnant_view -> select_for_class) must agree for
        any (work_frac, overhead): both delegate to ClockTable.remnant,
        and this pins that they can never drift apart."""
        pol = MinEnergy(V5E_DVFS)
        tab = self._table()
        mgr = PreemptionManager(PreemptionConfig(restore_s=0.7))
        for wf in (0.15, 0.5, 0.9):
            job = Job(app=APPS[0], arrival=0.0, deadline=100.0, job_id=0,
                      work_frac=wf, segment=1)
            via_api = pol.select_resume(job, 12.0, tab, work_frac=wf,
                                        overhead_s=0.7)
            via_engine = pol.select_for_class(
                job, 12.0, mgr.remnant_view(tab, job))
            assert via_api == via_engine
