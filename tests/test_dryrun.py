"""Integration tests for the dry-run launch path (subprocess with 8 fake
devices — the production 512-device pass runs via repro.launch.dryrun)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import dataclasses as dc
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.configs.base import SHAPES, reduce_for_smoke, ShapeSpec
    from repro.launch import dryrun as dr
    from repro.launch.mesh import make_mesh, set_mesh
    from repro.roofline import analysis as roofline

    mesh = make_mesh((4, 2), ("data", "model"))

    # small shapes so the compile stays quick
    shape_train = ShapeSpec("t", 128, 8, "train")
    shape_decode = ShapeSpec("d", 256, 8, "decode")

    for arch in ("smollm-360m", "mixtral-8x22b", "falcon-mamba-7b"):
        cfg = reduce_for_smoke(get_config(arch))
        cfg = dc.replace(cfg, param_dtype="bfloat16", remat="full")
        for shape in (shape_train, shape_decode):
            compiled = dr._compile(cfg, shape, mesh, 1)
            cost = roofline.cost_analysis(compiled)
            assert cost.get("flops", 0) > 0, (arch, shape.mode)
            mem = roofline.memory_stats(compiled)
            assert mem["total_bytes"] > 0
            print(f"{arch} {shape.mode} OK flops={cost['flops']:.2e}")

    # sanitize_spec: non-divisible dims degrade to unsharded
    s = dr.sanitize_spec(P("model", "data"), (51867, 64), mesh)  # odd dim
    assert tuple(s) == (None, "data"), s
    s = dr.sanitize_spec(P(("pod", "data"), None), (128, 4), mesh)
    assert tuple(s) == ("data", None), s  # 'pod' absent on this mesh

    # collective parsing: FSDP all-gathers must appear
    cfg = dc.replace(reduce_for_smoke(get_config("smollm-360m")),
                     param_dtype="bfloat16", scan_layers=False)
    compiled = dr._compile(cfg, shape_train, mesh, 1)
    stats = roofline.parse_collectives(compiled.as_text())
    assert stats.modeled_bytes > 0 and stats.counts, stats.counts
    print("collectives OK", stats.counts)

    # shard_map MoE: both variants must match the meshless oracle
    import jax.numpy as jnp
    from repro.models.moe import init_moe, moe, moe_sharded
    cfg = dc.replace(reduce_for_smoke(get_config("kimi-k2-1t-a32b")),
                     capacity_factor=4.0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)
    out_ref, _ = moe(p, x, cfg)   # no mesh in scope -> jit oracle path
    # E-sharded: tp=2, E=4
    with set_mesh(mesh):
        out_e, _ = jax.jit(lambda p, x: moe_sharded(p, x, cfg))(p, x)
    assert float(jnp.max(jnp.abs(out_ref - out_e))) < 2e-4
    # F-sharded: tp=8 > E=4
    mesh8 = make_mesh((1, 8), ("data", "model"))
    with set_mesh(mesh8):
        out_f, _ = jax.jit(lambda p, x: moe_sharded(p, x, cfg))(p, x)
    assert float(jnp.max(jnp.abs(out_ref - out_f))) < 2e-4
    # batch=1 (long-context decode): dp must degrade gracefully
    x1 = x[:1]
    with set_mesh(mesh):
        out_1, _ = jax.jit(lambda p, x: moe_sharded(p, x, cfg))(p, x1)
    ref_1, _ = moe(p, x1, cfg)
    assert float(jnp.max(jnp.abs(ref_1 - out_1))) < 2e-4
    print("MOE_SHARD_MAP_OK")
    print("ALL_OK")
""")


@pytest.mark.slow
def test_dryrun_small_mesh():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, cwd=ROOT, env=env, timeout=1200)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "ALL_OK" in r.stdout


def test_depth_plan_covers_all_archs():
    from repro.configs import ARCH_ALIASES, get_config
    from repro.launch import dryrun as dr
    for arch in ARCH_ALIASES:
        cfg = get_config(arch)
        l1, l2, n_units, mk = dr._depth_plan(cfg)
        assert l2 > l1 >= 1
        assert n_units > 0
        c1 = mk(l1)
        assert c1.n_layers == l1 and not c1.scan_layers


def test_model_flops_formulas():
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.roofline.analysis import model_flops
    cfg = get_config("qwen2.5-14b")
    mf_train = model_flops(cfg, SHAPES["train_4k"], 256)
    # 6 * 14.77e9 * (4096*256) / 256
    assert abs(mf_train - 6 * cfg.param_count() * 4096) / mf_train < 1e-6
    mf_dec = model_flops(cfg, SHAPES["decode_32k"], 256)
    assert abs(mf_dec - 2 * cfg.param_count() * 128 / 256) / mf_dec < 1e-6
    # MoE uses active params
    moe = get_config("mixtral-8x22b")
    mf = model_flops(moe, SHAPES["train_4k"], 256)
    assert mf < 6 * moe.param_count() * 4096  # < total-param count
