"""Vectorized decision core (PR 6): bit-identity and property pinning.

The batched engine (``batch_decide=True``, the default) must be
indistinguishable from the scalar engine in everything except wall time:
same records, same floats, same RNG draws, same tie-breaks. This suite
pins that contract from four directions:

* **Engine identity** — the full acceptance grid: every policy ×
  {uniform, heterogeneous} pools × {capless, binding cap} × preemption
  {absent, disabled, armed}, records compared field-for-field; plus a
  hypothesis-sampled sweep over seeds/quanta and a free-heap invariant
  check through the multi-class candidate gather (the scratch-list reuse
  must leave the heap a heap).
* **Compiled ladders** — :class:`~repro.core.batch_decide.DecisionCore`
  selections vs the scalar ``select_clock`` scans on randomized tables
  and budgets for the whole compilable family, including the d-dvfs
  first-accept recurrence and voltage-floor plateau ties, plus LRU/stats
  behavior of the ladder cache.
* **Batched joint scoring** — ``Policy.batch_scores`` over padded
  :class:`~repro.core.prediction_service.StackedTable` views vs the
  scalar ``select_device_clock`` loop, including single-clock ladders
  stacked against full-length ones (padding must never be admitted).
* **Service substrate** — stacked-view caching/epoch invalidation,
  batched prefetch row-identity, the kernel-routing knob's env override,
  and the cached measurement path vs ``Testbed.run``.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed in this container — deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.paper_suite import PAPER_APPS
from repro.core import (
    EnergyTimePredictor, PowerCapCoordinator, PredictionService,
    PredictorConfig, PreemptionConfig, PreemptionManager, Testbed,
    V5E_CLASS, V5E_DVFS, V5LITE_CLASS, V5P_CLASS, build_dataset,
    heterogeneous_workload, make_device_pool, profile_features,
    run_schedule, stream_workload,
)
from repro.core.batch_decide import DecisionCore
from repro.core.engine import EventEngine
from repro.core.gbdt import GBDTParams
from repro.core.policies import (DeviceCandidate, MinEnergy, PaperDDVFS,
                                 POLICY_NAMES, RiskAware, resolve_policy)
from repro.core.prediction_service import (
    ClockTable, DEFAULT_KERNEL_MIN_ROWS, KERNEL_MIN_ROWS_ENV, StackedTable,
    kernel_min_rows_default)
from repro.core.simulator import Measurement

APPS = list(PAPER_APPS)[:6]
SMALL = PredictorConfig(
    gbdt=GBDTParams(iterations=60, depth=3, learning_rate=0.15,
                    l2_leaf_reg=5.0),
    gbdt_time=GBDTParams(iterations=60, depth=3, learning_rate=0.15,
                         l2_leaf_reg=3.0),
)

#: The two pool shapes of the acceptance grid: a classless uniform pool
#: (per-device scalar decision) and a mixed pool (joint placement through
#: the candidate gather + stacked scorer).
_POOLS = (
    ("uniform", None, 4),
    ("hetero", make_device_pool((V5P_CLASS, 1), (V5E_CLASS, 2),
                                (V5LITE_CLASS, 1)), 4),
)

_OFF = PreemptionConfig(self_rescue=False, queue_rescue=False)
_ARMED = PreemptionConfig(margin=0.02, min_remnant_frac=0.02)


@functools.lru_cache(maxsize=1)
def _fixture():
    tb = Testbed(seed=0)
    X, yp, yt, _ = build_dataset(APPS, tb, seed=0)
    rng = np.random.default_rng(7)
    return {
        "testbed": tb,
        "predictor": EnergyTimePredictor(SMALL).fit(X, yp, yt),
        "features": {a.name: profile_features(a, tb, rng=rng)
                     for a in APPS},
    }


def _service() -> PredictionService:
    f = _fixture()
    return PredictionService(V5E_DVFS, predictor=f["predictor"],
                             app_features=f["features"],
                             testbed=f["testbed"])


@functools.lru_cache(maxsize=None)
def _shared_service(pool_idx: int) -> PredictionService:
    """One memoized service per pool shape — table caches shared across
    the whole grid so every identity case races decisions, not builds."""
    return _service()


def _jobs(pool_idx: int, seed: int, n: int = 40, quantum: float = 0.0):
    f = _fixture()
    _, pool, n_dev = _POOLS[pool_idx]
    if pool is None:
        jobs = list(stream_workload(APPS, f["testbed"], n_jobs=n,
                                    seed=seed, n_devices=n_dev))
    else:
        jobs = list(heterogeneous_workload(APPS, f["testbed"], pool,
                                           n_jobs=n, seed=seed))
    if quantum:
        jobs = [dataclasses.replace(j, checkpoint_quantum=quantum)
                for j in jobs]
    return jobs


@functools.lru_cache(maxsize=None)
def _cap_w(pool_idx: int) -> float:
    """A binding cluster cap: idle floor + 50% of the pool's aggregate
    worst-app max-clock sprint headroom."""
    f = _fixture()
    tb = f["testbed"]
    _, pool, n_dev = _POOLS[pool_idx]
    classes = pool if pool is not None else [None] * n_dev
    floor = sprint = 0.0
    for cls in classes:
        d = tb.dvfs if cls is None else cls.dvfs
        floor += tb.idle_power() if cls is None else cls.idle_power()
        sprint += max(tb.true_power(a, d.max_clock,
                                    dvfs=None if cls is None else d)
                      for a in APPS)
    return floor + 0.5 * (sprint - floor)


def _run(jobs, pool_idx: int, policy: str, cap: bool, preempt, batch: bool):
    f = _fixture()
    _, pool, n_dev = _POOLS[pool_idx]
    coord = (PowerCapCoordinator(_cap_w(pool_idx),
                                 grant_policy="greedy-edf")
             if cap else None)
    return run_schedule(
        jobs, policy, f["testbed"], service=_shared_service(pool_idx),
        n_devices=n_dev, device_classes=pool, power_coordinator=coord,
        preemption=preempt, batch_decide=batch)


def _assert_identical(a, b):
    assert len(a.records) == len(b.records)
    for i, (ra, rb) in enumerate(zip(a.records, b.records)):
        assert ra == rb, (i, ra, rb)


# ---------------------------------------------------------------------- #
#  Engine identity: batched records == scalar-oracle records
# ---------------------------------------------------------------------- #
class TestBatchedEngineIdentity:
    @pytest.mark.parametrize("policy", POLICY_NAMES)
    @pytest.mark.parametrize("pool_idx", range(len(_POOLS)),
                             ids=[p[0] for p in _POOLS])
    def test_acceptance_grid(self, policy, pool_idx):
        """The full grid: every policy × both pools × {capless, binding
        cap} × preemption {absent, disabled, armed} — the batched engine's
        records are bit-identical to the scalar oracle's (same floats,
        same RNG stream, same dispatch order, compare= fields included)."""
        for cap in (False, True):
            for pmode in ("none", "off", "armed"):
                quantum = 0.0 if pmode == "none" else 0.3
                jobs = _jobs(pool_idx, seed=3, quantum=quantum)
                mk = {"none": lambda: None,
                      "off": lambda: PreemptionManager(_OFF),
                      "armed": lambda: PreemptionManager(_ARMED)}[pmode]
                a = _run(jobs, pool_idx, policy, cap, mk(), batch=False)
                b = _run(jobs, pool_idx, policy, cap, mk(), batch=True)
                _assert_identical(a, b)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 50),
           pool_idx=st.integers(0, len(_POOLS) - 1),
           policy=st.sampled_from(list(POLICY_NAMES)),
           cap=st.sampled_from([False, True]),
           quantum=st.floats(0.05, 1.5))
    def test_sampled_streams(self, seed, pool_idx, policy, cap, quantum):
        """Random (seed, pool, policy, cap, quantum) draws: identity holds
        off the fixed acceptance seeds too."""
        jobs = _jobs(pool_idx, seed=seed, quantum=quantum)
        a = _run(jobs, pool_idx, policy, cap, PreemptionManager(_OFF),
                 batch=False)
        b = _run(jobs, pool_idx, policy, cap, PreemptionManager(_OFF),
                 batch=True)
        _assert_identical(a, b)

    def test_fast_paths_actually_engage(self):
        """The grid above must not pass vacuously: on the mixed pool the
        batchable policies take the stacked scorer, d-dvfs takes the
        per-row ladders, and the measurement cache serves repeat
        dispatches."""
        f = _fixture()
        _, pool, _ = _POOLS[1]
        svc = _shared_service(1)
        jobs = _jobs(1, seed=3)
        for policy, counter in (("min-energy", "batched_joint"),
                                ("d-dvfs", "ladder_joint")):
            eng = EventEngine(f["testbed"], policy, service=svc,
                              device_classes=pool)
            assert eng.batch_decide and eng._fast_measure
            eng.run(jobs)
            st_ = eng.decision_stats
            assert getattr(st_, counter) > 0, st_.summary()
            assert st_.measure_hits > 0

    def test_heap_invariant_through_candidate_gather(self):
        """Satellite: the multi-class gather reuses one scratch list pair
        across decisions; the free heap must satisfy the heap property
        after every single decision (losers pushed back, no aliasing
        between the scratch lists and the heap)."""
        f = _fixture()
        _, pool, _ = _POOLS[1]
        checked = {"n": 0}

        class CheckedEngine(EventEngine):
            def _decide(self, job, budget, start, dev, orig_free_t, free,
                        queue, coord, running=None, finalize=None):
                out = super()._decide(job, budget, start, dev, orig_free_t,
                                      free, queue, coord, running, finalize)
                for i in range(len(free)):
                    for c in (2 * i + 1, 2 * i + 2):
                        if c < len(free):
                            assert free[i] <= free[c], (i, c, free)
                # scratch lists must not alias live heap entries' storage
                assert self._co_free is not free and self._held is not free
                checked["n"] += 1
                return out

        eng = CheckedEngine(f["testbed"], "min-energy",
                            service=_shared_service(1),
                            device_classes=pool)
        res = eng.run(_jobs(1, seed=5))
        assert checked["n"] == len(res.records) > 0


# ---------------------------------------------------------------------- #
#  Compiled ladders vs the scalar scans
# ---------------------------------------------------------------------- #
def _rand_table(seed: int, L: int) -> ClockTable:
    rng = np.random.default_rng(seed)
    clocks = tuple(V5E_DVFS.clock_list()[:L])
    assert len(clocks) == L
    return ClockTable(clocks=clocks,
                      P=rng.uniform(20.0, 150.0, L),
                      T=rng.uniform(0.1, 10.0, L))


def _budgets(table: ClockTable):
    """Budgets hitting every interesting region: below min, every exact
    threshold, midpoints, above max."""
    Ts = np.sort(table.T)
    out = [float(Ts[0]) * 0.5, float(Ts[-1]) * 2.5]
    out.extend(float(t) for t in Ts)
    out.extend(float(t) * 1.01 for t in Ts)
    return out


class TestCompiledLadders:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), L=st.integers(1, 64),
           kind=st.sampled_from(["min-energy", "risk-aware", "oracle",
                                 "d-dvfs"]))
    def test_ladder_matches_scalar_scan(self, seed, L, kind):
        """Property: for random tables (single-clock ladders included) and
        budgets at/around every threshold, the compiled ladder returns the
        scalar ``select_clock``'s exact selection — same clock object,
        same floats."""
        table = _rand_table(seed, L)
        policy = resolve_policy(kind, V5E_DVFS)
        core = DecisionCore()
        for b in _budgets(table):
            want = policy.select_clock(None, b, table)
            got = core.select(policy, None, b, table)
            assert got == want, (kind, b, got, want)

    def test_plateau_ties_keep_lowest_ladder_index(self):
        """Voltage-floor plateau: equal energies across the feasible set —
        both paths must keep the lowest ladder index (np.argmin's
        first-occurrence rule)."""
        clocks = tuple(V5E_DVFS.clock_list()[:4])
        table = ClockTable(clocks=clocks,
                           P=np.array([3.0, 4.0, 6.0, 12.0]),
                           T=np.array([4.0, 3.0, 2.0, 1.0]))  # E == 12 all
        policy = MinEnergy(V5E_DVFS)
        core = DecisionCore()
        for b, want_i in ((4.5, 0), (3.5, 1), (2.5, 2), (1.5, 3)):
            want = policy.select_clock(None, b, table)
            got = core.select(policy, None, b, table)
            assert got == want
            assert got.clock is clocks[want_i], (b, got)

    def test_ddvfs_first_accept_recurrence(self):
        """Deterministic d-dvfs case: budget 3 on T=[2, 9, 1.5] accepts
        i=0 (tightening max_time to 2), rejects i=1 (9 ≥ 2), accepts i=2 —
        the ladder's precomputed outcome must replay that scan exactly."""
        clocks = tuple(V5E_DVFS.clock_list()[:3])
        table = ClockTable(clocks=clocks,
                           P=np.array([5.0, 3.0, 4.0]),
                           T=np.array([2.0, 9.0, 1.5]))
        policy = PaperDDVFS(V5E_DVFS)
        core = DecisionCore()
        want = policy.select_clock(None, 3.0, table)
        got = core.select(policy, None, 3.0, table)
        assert got == want
        assert got.clock is clocks[2] and got.time == 1.5
        # infeasible budget: nothing strictly under it
        assert core.select(policy, None, 1.5, table).clock is None
        assert policy.select_clock(None, 1.5, table).clock is None

    def test_ladder_cache_lru_and_stats(self):
        """Second decision on the same (table, margin) is a cache hit; the
        LRU bound evicts oldest; a distinct table object builds its own
        ladder (identity-keyed, never contents-keyed)."""
        core = DecisionCore(cache_size=4)
        policy = MinEnergy(V5E_DVFS)
        t0 = _rand_table(0, 8)
        core.select(policy, None, 1.0, t0)
        core.select(policy, None, 2.0, t0)
        assert core.stats.ladder_builds == 1
        assert core.stats.ladder_hits == 1
        twin = ClockTable(clocks=t0.clocks, P=t0.P.copy(), T=t0.T.copy())
        core.select(policy, None, 1.0, twin)
        assert core.stats.ladder_builds == 2
        for s in range(10):
            core.select(policy, None, 1.0, _rand_table(100 + s, 8))
        assert len(core._ladders) <= 4
        # margin is part of the key: RiskAware at two margins = two ladders
        core2 = DecisionCore()
        for m in (0.05, 0.2):
            core2.select(RiskAware(V5E_DVFS, margin=m), None, 1.0, t0)
        assert core2.stats.ladder_builds == 2


# ---------------------------------------------------------------------- #
#  Batched joint scoring vs the scalar candidate loop
# ---------------------------------------------------------------------- #
def _cands(tables):
    classes = [V5P_CLASS, V5E_CLASS, V5LITE_CLASS]
    return [DeviceCandidate(classes[i % len(classes)], 0.0, t)
            for i, t in enumerate(tables)]


def _joint_case(policy, tables, budget):
    cands = [dataclasses.replace(c, budget=budget) for c in _cands(tables)]
    want = policy.select_device_clock(None, cands)
    got = policy.batch_scores(None, budget, StackedTable.from_tables(tables))
    assert got is not None
    assert got == want, (budget, got, want)


class TestBatchScores:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000),
           kind=st.sampled_from(["min-energy", "risk-aware", "oracle"]))
    def test_matches_scalar_joint_decision(self, seed, kind):
        """Property: mixed-length candidate ladders (a single-clock ladder
        stacked against 24- and 64-clock ones) at budgets around every
        threshold — ``batch_scores`` returns ``select_device_clock``'s
        exact (index, selection), padding never admitted."""
        policy = resolve_policy(kind, V5E_DVFS)
        tables = [_rand_table(seed, 1), _rand_table(seed + 1, 24),
                  _rand_table(seed + 2, 64)]
        allT = np.concatenate([t.T for t in tables])
        budgets = [float(allT.min()) * 0.5, float(allT.max()) * 3.0]
        budgets.extend(float(t) for t in np.sort(allT)[::5])
        for b in budgets:
            _joint_case(policy, tables, b)

    def test_plateau_and_cross_candidate_ties(self):
        """Equal energies inside a row keep the lowest ladder index; equal
        best scores across candidates keep the earliest-free (lowest)
        candidate — the strict-< rule, exactly."""
        clocks = tuple(V5E_DVFS.clock_list()[:3])
        ta = ClockTable(clocks=clocks, P=np.array([6.0, 4.0, 3.0]),
                        T=np.array([2.0, 3.0, 4.0]))       # E == 12 all
        tb_ = ClockTable(clocks=clocks, P=np.array([12.0, 6.0, 4.0]),
                         T=np.array([1.0, 2.0, 3.0]))      # E == 12 all
        policy = MinEnergy(V5E_DVFS)
        for budget in (5.0, 2.5):
            _joint_case(policy, [ta, tb_], budget)
        got = policy.batch_scores(None, 5.0,
                                  StackedTable.from_tables([ta, tb_]))
        assert got[0] == 0 and got[1].clock is clocks[0]

    def test_infeasible_everywhere(self):
        """No feasible clock on any candidate: both paths fall back to the
        best-min-T candidate with a ClockSelection(None) verdict."""
        tables = [_rand_table(7, 1), _rand_table(8, 24)]
        policy = MinEnergy(V5E_DVFS)
        tiny = 0.5 * min(float(t.T.min()) for t in tables)
        _joint_case(policy, tables, tiny)
        got = policy.batch_scores(None, tiny,
                                  StackedTable.from_tables(tables))
        assert got[1].clock is None

    def test_non_batchable_policies_opt_out(self):
        """Scan-order and fixed-clock policies return None — the engine
        must take the scalar/ladder path, never a silent approximation."""
        stk = StackedTable.from_tables([_rand_table(0, 8)])
        for kind in ("d-dvfs", "dc", "mc"):
            policy = resolve_policy(kind, V5E_DVFS)
            assert policy.batch_scores(None, 1.0, stk) is None

    def test_padding_shape_and_mask(self):
        """The stacked view pads with +inf (never feasible) and masks
        padded slots out of row minima."""
        stk = StackedTable.from_tables([_rand_table(0, 1),
                                        _rand_table(1, 64)])
        assert stk.P.shape == stk.T.shape == stk.mask.shape == (2, 64)
        assert stk.lengths == (1, 64)
        assert np.isinf(stk.T[0, 1:]).all() and np.isinf(stk.P[0, 1:]).all()
        assert not stk.mask[0, 1:].any() and stk.mask[1].all()


# ---------------------------------------------------------------------- #
#  Service substrate: stacked cache, prefetch, kernel knob
# ---------------------------------------------------------------------- #
class _NudgeCorrector:
    def correct(self, name, clocks, P, T):
        return P * 1.01, T


class TestServiceSubstrate:
    def test_stacked_cache_identity_and_epoch(self):
        svc = _service()
        classes = (V5P_CLASS, V5E_CLASS)
        name = APPS[0].name
        s1 = svc.stacked_tables(name, classes)
        s2 = svc.stacked_tables(name, classes)
        assert s1 is s2
        assert svc.stats.stacked_builds == 1
        assert svc.stats.stacked_hits == 1
        # rows are the very objects per-app decisions would fetch
        for row, cls in zip(s1.tables, classes):
            assert row is svc.table(name, cls)
        # corrector attach bumps the epoch: cached views are void
        svc.attach_corrector(_NudgeCorrector())
        s3 = svc.stacked_tables(name, classes)
        assert s3 is not s1 and svc.stats.stacked_builds == 2
        assert s3.tables[0] is svc.table(name, V5P_CLASS)
        # targeted invalidation voids again
        svc.invalidate(name)
        assert svc.stacked_tables(name, classes) is not s3
        svc.detach_corrector()
        s5 = svc.stacked_tables(name, classes)
        assert np.array_equal(s5.P, s1.P) and np.array_equal(s5.T, s1.T)

    def test_stacked_cache_lru_bound(self):
        svc = _service()
        svc.stacked_cache_size = 3
        for a in APPS:
            svc.stacked_tables(a.name, (V5E_CLASS,))
        assert len(svc._stacked) <= 3

    def test_prefetch_rows_bit_identical_to_lazy(self):
        """Batched prefetch (one stacked predictor call per class ×
        regressor) must produce byte-identical tables to one-at-a-time
        lazy builds — the GBDT is rowwise, so slicing commutes with
        predicting."""
        lazy, pre = _service(), _service()
        names = [a.name for a in APPS]
        classes = (None, V5LITE_CLASS)
        built = pre.prefetch_tables(names, classes)
        assert built == len(names) * len(classes)
        assert pre.stats.prefetched_tables == built
        for cls in classes:
            for n in names:
                a, b = lazy.table(n, cls), pre.table(n, cls)
                assert np.array_equal(a.P, b.P), (n, cls)
                assert np.array_equal(a.T, b.T), (n, cls)
                assert a.clocks == b.clocks
        # a second prefetch finds nothing missing
        assert pre.prefetch_tables(names, classes) == 0

    def test_kernel_min_rows_env_override(self, monkeypatch):
        monkeypatch.delenv(KERNEL_MIN_ROWS_ENV, raising=False)
        assert kernel_min_rows_default() == DEFAULT_KERNEL_MIN_ROWS
        monkeypatch.setenv(KERNEL_MIN_ROWS_ENV, "7")
        assert kernel_min_rows_default() == 7
        monkeypatch.setenv(KERNEL_MIN_ROWS_ENV, "not-a-number")
        assert kernel_min_rows_default() == DEFAULT_KERNEL_MIN_ROWS
        svc = PredictionService(V5E_DVFS)
        assert svc.kernel_min_rows == DEFAULT_KERNEL_MIN_ROWS


# ---------------------------------------------------------------------- #
#  Cached measurement substrate
# ---------------------------------------------------------------------- #
class TestMeasureCache:
    def test_measure_bit_identical_to_testbed_run(self):
        """Same rng state in, same Measurement out — including repeat
        (app, clock) pairs served from the truth cache (the noise draws
        still advance the stream identically)."""
        f = _fixture()
        tb = f["testbed"]
        core = DecisionCore()
        clocks = tb.dvfs.clock_list()
        seq = [(APPS[i % len(APPS)], clocks[(i * 7) % 5])
               for i in range(40)]  # (app, clock) pairs recur past i=30
        r1, r2 = np.random.default_rng(11), np.random.default_rng(11)
        for app, clock in seq:
            want = tb.run(app, clock, rng=r1)
            got = core.measure(tb, app, clock, r2)
            assert isinstance(got, Measurement)
            assert got == want, (app.name, clock)
        assert core.stats.measure_hits > 0
        assert core.stats.measure_builds <= len(APPS) * len(clocks)
        # per-class dvfs keys separately
        got = core.measure(tb, APPS[0], V5LITE_CLASS.dvfs.clock_list()[0],
                           np.random.default_rng(1),
                           dvfs=V5LITE_CLASS.dvfs)
        want = tb.run(APPS[0], V5LITE_CLASS.dvfs.clock_list()[0],
                      rng=np.random.default_rng(1), dvfs=V5LITE_CLASS.dvfs)
        assert got == want

    def test_fast_measure_gate_rejects_subclassed_physics(self):
        f = _fixture()
        assert DecisionCore.fast_measure_safe(f["testbed"])

        class WarpedTestbed(Testbed):
            def true_time(self, app, clock, dvfs=None):
                return super().true_time(app, clock, dvfs=dvfs) * 2

        assert not DecisionCore.fast_measure_safe(WarpedTestbed(seed=0))
        f2 = _fixture()
        eng = EventEngine(WarpedTestbed(seed=0), "min-energy",
                          service=_service())
        assert not eng._fast_measure
