"""Substrate tests: optimizer, data pipeline, checkpointing, fault
tolerance (restart bit-exactness, straggler mitigation), gradient
compression, and multi-device behaviors (subprocess with 8 fake devices)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.configs import get_config
from repro.configs.base import reduce_for_smoke
from repro.core.dvfs import ClockPair, V5E_DVFS
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist.fault_tolerance import (FailureInjector, RunnerConfig,
                                        SimulatedFailure, StragglerMonitor,
                                        TrainingRunner)
from repro.models import model
from repro.optim import adamw
from repro.train.step import make_train_step


# ---------------------------------------------------------------------- #
#  Optimizer
# ---------------------------------------------------------------------- #
class TestAdamW:
    def test_minimizes_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                                weight_decay=0.0, grad_clip=1e9)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw.init(params, cfg)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw.update(params, grads, state, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_int8_state_tracks_fp32(self):
        """8-bit Adam's contract is trajectory-level: the compressed-state
        update direction matches fp32 (high cosine similarity; median
        coordinate error small), at <45% of the state bytes. Per-coordinate
        max error is NOT bounded (small-|g| coordinates quantize coarsely) —
        the loss-trajectory equivalence is covered by the arch train tests."""
        k = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(k, (4, 256))}
        g = jax.random.normal(jax.random.PRNGKey(1), (4, 256)) * 0.1
        cfg32 = adamw.AdamWConfig(lr=1e-2, warmup_steps=0, weight_decay=0.0)
        cfg8 = adamw.AdamWConfig(lr=1e-2, warmup_steps=0, weight_decay=0.0,
                                 state_dtype="int8")
        p32, s32 = dict(params), adamw.init(params, cfg32)
        p8, s8 = dict(params), adamw.init(params, cfg8)
        for _ in range(10):
            p32, s32, _ = adamw.update(p32, {"w": g}, s32, cfg32)
            p8, s8, _ = adamw.update(p8, {"w": g}, s8, cfg8)
        d32 = (p32["w"] - params["w"]).ravel()
        d8 = (p8["w"] - params["w"]).ravel()
        cos = float(jnp.dot(d32, d8)
                    / (jnp.linalg.norm(d32) * jnp.linalg.norm(d8) + 1e-12))
        assert cos > 0.98, cos
        med = float(jnp.median(jnp.abs(d32 - d8) / (jnp.abs(d32) + 1e-12)))
        assert med < 0.15, med
        # memory layout + savings
        assert s8.m["w"].q.shape == params["w"].shape
        assert s8.m["w"].q.dtype == jnp.int8
        bytes8 = (s8.m["w"].q.nbytes + s8.m["w"].scale.nbytes
                  + s8.v["w"].nbytes)
        bytes32 = s32.m["w"].nbytes + s32.v["w"].nbytes
        assert bytes8 < 0.45 * bytes32

    def test_grad_clip(self):
        cfg = adamw.AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
        params = {"w": jnp.zeros(3)}
        state = adamw.init(params, cfg)
        _, _, m = adamw.update(params, {"w": jnp.full(3, 100.0)}, state, cfg)
        assert float(m["grad_norm"]) > 100

    def test_lr_schedule(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_frac=0.1)
        assert float(adamw.lr_at(jnp.int32(5), cfg)) == pytest.approx(0.5)
        assert float(adamw.lr_at(jnp.int32(10), cfg)) == pytest.approx(1.0)
        assert float(adamw.lr_at(jnp.int32(100), cfg)) == pytest.approx(0.1)


# ---------------------------------------------------------------------- #
#  Data pipeline
# ---------------------------------------------------------------------- #
class TestData:
    def test_deterministic(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
        a = SyntheticLM(cfg).batch(3)
        b = SyntheticLM(cfg).batch(3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
        b = SyntheticLM(cfg).batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_sharding_partitions_batch(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8)
        src = SyntheticLM(cfg)
        shards = [src.batch(0, host_index=i, host_count=4) for i in range(4)]
        assert all(s["tokens"].shape == (2, 8) for s in shards)
        # different hosts get different data
        assert not np.array_equal(shards[0]["tokens"], shards[1]["tokens"])


# ---------------------------------------------------------------------- #
#  Checkpointing
# ---------------------------------------------------------------------- #
class TestCheckpoint:
    def _tree(self):
        return {
            "params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones(4, jnp.bfloat16)},
            "step": jnp.int32(7),
        }

    def test_roundtrip_bit_exact(self, tmp_path):
        tree = self._tree()
        ckpt.save(str(tmp_path), 7, tree)
        restored, manifest = ckpt.restore(str(tmp_path), tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert manifest["step"] == 7

    def test_latest_step_and_gc(self, tmp_path):
        tree = self._tree()
        saver = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            saver.save(s, tree)
        saver.wait()
        assert ckpt.latest_step(str(tmp_path)) == 4
        steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step"))
        assert len(steps) == 2

    def test_corruption_detected(self, tmp_path):
        tree = self._tree()
        path = ckpt.save(str(tmp_path), 1, tree)
        # corrupt one payload
        victim = [f for f in os.listdir(path) if f.endswith(".npy")][0]
        arr = np.load(os.path.join(path, victim))
        arr_flat = arr.reshape(-1).copy()
        arr_flat[0] += 1
        np.save(os.path.join(path, victim), arr_flat.reshape(arr.shape))
        with pytest.raises(IOError):
            ckpt.restore(str(tmp_path), tree, step=1)

    def test_quantstate_leaves_roundtrip(self, tmp_path):
        params = {"w": jax.random.normal(jax.random.PRNGKey(0), (2, 256))}
        cfg = adamw.AdamWConfig(state_dtype="int8")
        state = adamw.init(params, cfg)
        tree = {"opt": state}
        ckpt.save(str(tmp_path), 0, tree)
        restored, _ = ckpt.restore(str(tmp_path), tree)
        np.testing.assert_array_equal(np.asarray(restored["opt"].m["w"].q),
                                      np.asarray(state.m["w"].q))


# ---------------------------------------------------------------------- #
#  Fault tolerance
# ---------------------------------------------------------------------- #
class TestFaultTolerance:
    def _setup(self, tmp_path):
        cfg = reduce_for_smoke(get_config("smollm-360m"))
        params = model.init(cfg, jax.random.PRNGKey(0))
        ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=50)
        opt = adamw.init(params, ocfg)
        step = jax.jit(make_train_step(cfg, ocfg))
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                      global_batch=4, seed=0))

        def data_fn(s):
            return {k: jnp.asarray(v) for k, v in data.batch(s).items()}

        return params, opt, step, data_fn

    def test_restart_bit_exact(self, tmp_path):
        """A run with an injected failure + restart matches the uninterrupted
        run bit-for-bit (deterministic pipeline + checkpointed state)."""
        params, opt, step, data_fn = self._setup(tmp_path)

        clean = TrainingRunner(
            RunnerConfig(ckpt_dir=str(tmp_path / "a"), ckpt_interval=4),
            step, data_fn)
        p_clean, _, _ = clean.run(params, opt, 0, 10)

        faulty = TrainingRunner(
            RunnerConfig(ckpt_dir=str(tmp_path / "b"), ckpt_interval=4),
            step, data_fn, injector=FailureInjector(fail_at=(6,)))
        p_fault, _, _ = faulty.run(params, opt, 0, 10)
        assert faulty.restarts == 1

        for a, b in zip(jax.tree.leaves(p_clean), jax.tree.leaves(p_fault)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_exceeding_max_restarts_raises(self, tmp_path):
        params, opt, step, data_fn = self._setup(tmp_path)
        runner = TrainingRunner(
            RunnerConfig(ckpt_dir=str(tmp_path / "c"), ckpt_interval=100,
                         max_restarts=1),
            step, data_fn,
            injector=FailureInjector(fail_at=(2, 3)))
        # failing twice at the same restart point (ckpt_interval=100 means we
        # restart to step 0 and hit step 2/3 again) exceeds max_restarts=1
        with pytest.raises(SimulatedFailure):
            runner.run(params, opt, 0, 6)

    def test_straggler_detection_and_dvfs_boost(self):
        mon = StragglerMonitor(n_replicas=8, dvfs=V5E_DVFS, threshold=1.4)
        base = np.full(8, 1.0)
        for _ in range(10):
            times = base.copy()
            times[3] = 2.0  # replica 3 runs 2x slow
            flagged = mon.observe(times)
        assert flagged == [3]
        cur = V5E_DVFS.default_clock
        new = mon.mitigation_clock(3, cur)
        assert new.s_core > cur.s_core  # clock boosted
        # still slow at max clock → evict
        mon.boosts[3] = ClockPair(max(V5E_DVFS.core_scales), 1.0)
        assert mon.should_evict(3)
        assert not mon.should_evict(0)

    def test_recovered_straggler_resets_ladder(self):
        # a boosted replica that drops back under threshold must not be
        # evictable on its stale max-clock boost — recovery clears it
        mon = StragglerMonitor(n_replicas=4, dvfs=V5E_DVFS, threshold=1.4)
        slow = np.full(4, 1.0)
        slow[2] = 3.0
        for _ in range(10):
            mon.observe(slow.copy())
        assert 2 in mon.flagged
        mon.boosts[2] = ClockPair(max(V5E_DVFS.core_scales), 1.0)
        assert mon.should_evict(2)
        for _ in range(30):  # replica 2 recovers to fleet speed
            flagged = mon.observe(np.full(4, 1.0))
        assert 2 not in flagged
        assert 2 not in mon.boosts  # ladder reset on recovery
        assert not mon.should_evict(2)
        # a later relapse starts the ladder from scratch
        for _ in range(10):
            mon.observe(slow.copy())
        assert 2 in mon.flagged
        assert not mon.should_evict(2)

    def test_package_level_exports(self):
        import repro.dist as dist
        for name in ("StragglerMonitor", "FailureInjector",
                     "TrainingRunner", "RunnerConfig", "SimulatedFailure"):
            assert getattr(dist, name) is not None
            assert name in dist.__all__

    def test_no_false_positives_on_uniform_fleet(self):
        mon = StragglerMonitor(n_replicas=16, dvfs=V5E_DVFS)
        rng = np.random.default_rng(0)
        for _ in range(20):
            flagged = mon.observe(1.0 + 0.05 * rng.standard_normal(16))
        assert flagged == []


# ---------------------------------------------------------------------- #
#  Multi-device semantics (subprocess: 8 fake CPU devices)
# ---------------------------------------------------------------------- #
MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from functools import partial
    import tempfile, sys
    sys.path.insert(0, "src")
    from repro.ckpt import checkpoint as ckpt
    from repro.dist.collectives import compressed_psum, init_error
    from repro.launch.mesh import make_mesh
    from repro.models.common import shard_map

    # --- elastic checkpoint reshard: save on 8-dev mesh, restore on 4 ----
    mesh8 = make_mesh((4, 2), ("data", "model"))
    w = jnp.arange(64.0).reshape(8, 8)
    w8 = jax.device_put(w, NamedSharding(mesh8, P("data", "model")))
    d = tempfile.mkdtemp()
    ckpt.save(d, 0, {"w": w8})
    mesh4 = make_mesh((2, 2), ("data", "model"),
                      devices=jax.devices()[:4])
    restored, _ = ckpt.restore(d, {"w": w}, mesh=mesh4,
                               specs={"w": P("data", "model")})
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
    assert len(restored["w"].sharding.device_set) == 4
    print("ELASTIC_OK")

    # --- compressed gradient psum over a pod axis with error feedback ----
    mesh = make_mesh((2, 4), ("pod", "data"))
    g = jax.random.normal(jax.random.PRNGKey(0), (2, 256))

    @partial(shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")),
             out_specs=(P("pod"), P("pod")))
    def reduce_fn(g_local, err):
        out, new_err = compressed_psum({"g": g_local}, "pod",
                                       {"g": err})
        return out["g"], new_err["g"]

    err0 = jnp.zeros_like(g)
    out, err = reduce_fn(g, err0)
    exact = jnp.mean(g.reshape(2, 1, 256), axis=0, keepdims=True)
    exact = jnp.broadcast_to(exact, (2, 1, 256)).reshape(2, 256)
    rel = float(jnp.max(jnp.abs(out - exact)) / jnp.max(jnp.abs(exact)))
    assert rel < 0.05, rel
    # error feedback: residual is the quantization error, bounded by scale
    assert float(jnp.max(jnp.abs(err))) <= float(jnp.max(jnp.abs(g))) / 127 + 1e-6
    print("PSUM_OK", rel)
""")


def test_multidevice_elastic_and_compression():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                       capture_output=True, text=True, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ELASTIC_OK" in r.stdout
    assert "PSUM_OK" in r.stdout
