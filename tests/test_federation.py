"""Tests for the multi-rack federation subsystem (repro.core.federation):
topology partition exactness, cap-transfer primitives, facility share
splits and hierarchical escalation, single-rack ≡ bare-coordinator
bit-identity across all six policies, the facility-cap-safety fuzz
(granted-ledger peak ≤ cap for every racks × sizes × cap × grant-policy
draw), and straggler quarantine/migration mechanics."""
import functools
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed in this container — deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.paper_suite import PAPER_APPS
from repro.core import (
    FACILITY_SHARE_POLICIES, EnergyTimePredictor, FacilityCoordinator,
    FederatedPreemptionManager, GRANT_POLICIES, MigrationCostModel,
    POLICIES, PowerCapCoordinator, PowerTelemetry, PredictorConfig,
    PreemptionConfig, RackTopology, Testbed, V5E_DVFS, build_dataset,
    multi_rack_workload, profile_features, run_schedule,
)
from repro.core.dvfs import ClockPair
from repro.core.gbdt import GBDTParams

APPS = list(PAPER_APPS)[:6]
SMALL = PredictorConfig(
    gbdt=GBDTParams(iterations=60, depth=3, learning_rate=0.15,
                    l2_leaf_reg=5.0),
    gbdt_time=GBDTParams(iterations=60, depth=3, learning_rate=0.15,
                         l2_leaf_reg=3.0),
)


@pytest.fixture(scope="module")
def testbed():
    return Testbed(seed=0)


@pytest.fixture(scope="module")
def fitted(testbed):
    X, yp, yt, _ = build_dataset(APPS, testbed, seed=0)
    return EnergyTimePredictor(SMALL).fit(X, yp, yt)


@pytest.fixture(scope="module")
def app_feats(testbed):
    rng = np.random.default_rng(7)
    return {a.name: profile_features(a, testbed, rng=rng) for a in APPS}


@functools.lru_cache(maxsize=1)
def _fixture():
    """lru-cached twin of the module fixtures for the hypothesis fuzz —
    the shim's ``given`` wrapper is signature-opaque to pytest, so fuzz
    tests cannot take fixture arguments."""
    tb = Testbed(seed=0)
    X, yp, yt, _ = build_dataset(APPS, tb, seed=0)
    rng = np.random.default_rng(7)
    return {
        "testbed": tb,
        "predictor": EnergyTimePredictor(SMALL).fit(X, yp, yt),
        "features": {a.name: profile_features(a, tb, rng=rng)
                     for a in APPS},
    }


# ---------------------------------------------------------------------- #
#  Topology: racks partition the pool (invariant 3)
# ---------------------------------------------------------------------- #
class TestRackTopology:
    def test_partition_exact(self):
        topo = RackTopology((2, 3, 1))
        assert topo.n_racks == 3
        assert topo.n_devices == 6
        assert topo.offsets == (0, 2, 5)
        seen = []
        for r in range(topo.n_racks):
            seen.extend(topo.devices_of(r))
        # every device on exactly one rack, in global order
        assert seen == list(range(6))
        for d in range(6):
            r = topo.rack_of(d)
            assert d in topo.devices_of(r)
            assert topo.local_of(d) == d - topo.offsets[r]

    def test_out_of_range_raises(self):
        topo = RackTopology((2, 2))
        with pytest.raises(IndexError):
            topo.rack_of(4)
        with pytest.raises(IndexError):
            topo.rack_of(-1)

    def test_bad_sizes_raise(self):
        with pytest.raises(ValueError):
            RackTopology(())
        with pytest.raises(ValueError):
            RackTopology((2, 0))

    @settings(max_examples=20, deadline=None)
    @given(sizes=st.lists(st.integers(1, 7), min_size=1, max_size=5))
    def test_partition_fuzz(self, sizes):
        topo = RackTopology(tuple(sizes))
        owners = [topo.rack_of(d) for d in range(topo.n_devices)]
        # non-decreasing rack ids, each rack owns exactly its size
        assert owners == sorted(owners)
        for r, s in enumerate(sizes):
            assert owners.count(r) == s


# ---------------------------------------------------------------------- #
#  Migration cost model
# ---------------------------------------------------------------------- #
class TestMigrationCostModel:
    def test_zero_bytes_is_overhead_only(self):
        m = MigrationCostModel()
        secs, joules = m.cost(0.0)
        assert secs == pytest.approx(m.overhead_s)
        assert joules == 0.0

    def test_linear_then_clamped(self):
        m = MigrationCostModel(link_gbps=100.0, overhead_s=0.01,
                               joules_per_gb=10.0, max_bytes=8e9)
        s1, j1 = m.cost(1e9)
        assert s1 == pytest.approx(0.01 + 8.0 / 100.0)
        assert j1 == pytest.approx(10.0)
        # hbm traffic far above resident state clamps at max_bytes
        s_cap, j_cap = m.cost(8e9)
        assert m.cost(500e9) == (pytest.approx(s_cap),
                                 pytest.approx(j_cap))

    def test_negative_bytes_clamped_to_zero(self):
        m = MigrationCostModel()
        assert m.cost(-5.0) == (pytest.approx(m.overhead_s), 0.0)


# ---------------------------------------------------------------------- #
#  Cap-transfer primitives on the rack coordinator
# ---------------------------------------------------------------------- #
class TestCapTransfer:
    def _coord(self, cap=300.0, idle=(20.0, 20.0)):
        c = PowerCapCoordinator(cap)
        c.reset(list(idle))
        return c

    def test_release_cap_moves_only_free_headroom(self):
        c = self._coord(cap=300.0)
        got = c.release_cap(100.0)
        assert got == pytest.approx(100.0)
        assert c.cap_w == pytest.approx(200.0)
        # headroom shrank by exactly what was released
        assert c.headroom_w == pytest.approx(200.0 - c.allocated_w)

    def test_release_cap_bounded_by_headroom(self):
        c = self._coord(cap=300.0)
        free = c.headroom_w
        got = c.release_cap(1e9)
        assert got == pytest.approx(free)
        assert c.cap_w == pytest.approx(300.0 - free)
        # nothing left to give
        assert c.release_cap(10.0) == 0.0

    def test_release_cap_infinite_or_nonpositive_noop(self):
        c = PowerCapCoordinator(math.inf)
        c.reset([20.0])
        assert c.release_cap(50.0) == 0.0
        c2 = self._coord()
        assert c2.release_cap(0.0) == 0.0
        assert c2.cap_w == pytest.approx(300.0)

    def test_resize_below_allocations_raises(self):
        c = self._coord(cap=300.0)
        c.commit(0, 120.0, end=5.0, drawn_w=110.0)
        with pytest.raises(ValueError):
            c.resize_cap(c.allocated_w - 1.0)
        # at or above allocations is fine
        c.resize_cap(c.allocated_w)
        assert c.cap_w == pytest.approx(c.allocated_w)

    def test_reclaim_unused_returns_freed_watts(self):
        c = self._coord(cap=400.0)
        c.commit(0, 150.0, end=5.0, drawn_w=100.0)
        # grant 150 but draw 100 → 50 W reclaimable above the measured
        assert c.reclaimable_w == pytest.approx(50.0)
        freed = c.reclaim_unused()
        assert freed == pytest.approx(50.0)
        assert c.reclaimable_w == 0.0


# ---------------------------------------------------------------------- #
#  Facility share splits
# ---------------------------------------------------------------------- #
class TestFacilityShares:
    IDLE = [20.0] * 6

    def _fac(self, cap, sizes, **kw):
        fac = FacilityCoordinator(cap, sizes, **kw)
        fac.reset(self.IDLE[:fac.n_devices])
        return fac

    @pytest.mark.parametrize("share", FACILITY_SHARE_POLICIES)
    def test_split_sums_to_cap(self, share):
        fac = self._fac(500.0, [2, 3, 1], share_policy=share)
        caps = fac.caps()
        assert math.fsum(caps) <= 500.0 + 1e-9
        # every rack got at least its idle floor
        for r, c in enumerate(caps):
            assert c >= 20.0 * fac.topology.rack_sizes[r] - 1e-9

    def test_single_rack_gets_cap_exactly(self):
        cap = 313.7300000001
        fac = self._fac(cap, [4])
        assert fac.caps() == [cap]     # float-exact, no split arithmetic

    def test_infinite_cap_propagates(self):
        fac = self._fac(math.inf, [2, 2])
        assert fac.caps() == [math.inf, math.inf]

    def test_cap_below_idle_floor_raises(self):
        fac = FacilityCoordinator(50.0, [2, 2])
        with pytest.raises(ValueError):
            fac.reset(self.IDLE[:4])   # idle floor is 80 W

    def test_unknown_policies_raise(self):
        with pytest.raises(ValueError):
            FacilityCoordinator(100.0, [2], share_policy="nope")
        with pytest.raises(ValueError):
            FacilityCoordinator(100.0, [2], grant_policy="nope")
        with pytest.raises(ValueError):
            FacilityCoordinator(-1.0, [2])

    def test_pool_size_mismatch_raises(self):
        fac = FacilityCoordinator(500.0, [2, 2])
        with pytest.raises(ValueError):
            fac.reset([20.0] * 3)

    @pytest.mark.parametrize("share", ("demand-weighted", "tier-weighted"))
    def test_rebalance_preserves_cap_sum(self, share):
        fac = self._fac(500.0, [2, 2, 2], share_policy=share)
        # load rack 0 so rebalancing tilts headroom toward it
        fac.commit(0, 100.0, end=10.0, drawn_w=90.0)
        fac.commit(1, 100.0, end=10.0, drawn_w=90.0)
        fac.advance(1.0)
        assert fac.stats.rebalances >= 1
        assert math.fsum(fac.caps()) <= 500.0 + 1e-9
        # a loaded rack's floor (its allocations) is always covered
        for rack in fac.racks:
            assert rack.coord.cap_w >= rack.coord.allocated_w - 1e-9

    def test_static_never_rebalances(self):
        fac = self._fac(500.0, [2, 2, 2], share_policy="static")
        before = fac.caps()
        fac.commit(0, 100.0, end=10.0, drawn_w=90.0)
        fac.advance(1.0)
        assert fac.stats.rebalances == 0
        # grants expire at advance(20) but caps stay the static split
        fac.advance(20.0)
        assert fac.caps() == before


# ---------------------------------------------------------------------- #
#  Hierarchical escalation
# ---------------------------------------------------------------------- #
class TestEscalation:
    def _fac(self, **kw):
        fac = FacilityCoordinator(400.0, [2, 2], share_policy="static",
                                  **kw)
        fac.reset([20.0] * 4)
        return fac

    def test_sibling_cap_moves_on_escalation(self):
        fac = self._fac()
        cap0, cap1 = fac.caps()
        # rack 0 wants more than its whole slice
        need = cap0 + 50.0
        got = fac.escalate(0, need, start=0.0)
        assert got >= need - 1e-9
        assert fac.stats.escalations == 1
        assert fac.stats.rescues == 1
        assert fac.stats.transfers >= 1
        # watts conserved: what rack 0 gained, rack 1 + pool lost
        assert math.fsum(fac.caps()) <= 400.0 + 1e-9
        assert fac.caps()[0] > cap0
        assert fac.caps()[1] < cap1

    def test_escalation_disabled_stays_local(self):
        fac = self._fac(escalation=False)
        cap0 = fac.caps()[0]
        got = fac.escalate(0, cap0 + 50.0, start=0.0)
        assert got <= cap0 + 1e-9
        assert fac.stats.escalations == 0
        assert fac.caps()[0] == cap0

    def test_local_coverage_never_escalates(self):
        fac = self._fac()
        got = fac.escalate(0, 30.0, start=0.0)   # well inside rack 0's cap
        assert got >= 30.0 - 1e-9
        assert fac.stats.escalations == 0

    def test_potential_includes_sibling_spare(self):
        fac = self._fac()
        local_only = self._fac(escalation=False)
        assert fac.potential_w(0) > local_only.potential_w(0)


# ---------------------------------------------------------------------- #
#  Single-rack identity (invariant 2): all six policies
# ---------------------------------------------------------------------- #
class TestSingleRackIdentity:
    @pytest.mark.parametrize("policy", POLICIES)
    def test_bit_identical_to_bare_coordinator(self, policy, testbed,
                                               fitted, app_feats):
        jobs = list(multi_rack_workload(APPS, testbed, n_devices=3,
                                        n_jobs=30, seed=5))
        kw = dict(predictor=fitted, app_features=app_feats, n_devices=3)
        for grant in GRANT_POLICIES:
            fed = FacilityCoordinator(430.0, [3], grant_policy=grant)
            bare = PowerCapCoordinator(430.0, grant_policy=grant)
            r1 = run_schedule(jobs, policy, Testbed(seed=1000),
                              power_coordinator=fed, **kw)
            r2 = run_schedule(jobs, policy, Testbed(seed=1000),
                              power_coordinator=bare, **kw)
            assert len(r1.records) == len(r2.records)
            for a, b in zip(r1.records, r2.records):
                # rack provenance is the *only* allowed difference
                assert a == b, (policy, grant, a, b)
                assert (a.start, a.end, a.energy_j, a.power_grant_w) == \
                    (b.start, b.end, b.energy_j, b.power_grant_w)
                assert a.rack == 0 and b.rack is None
            assert r1.migrations == 0


# ---------------------------------------------------------------------- #
#  Facility cap safety fuzz (invariant 1)
# ---------------------------------------------------------------------- #
class TestFacilityCapSafety:
    @settings(max_examples=8, deadline=None)
    @given(
        sizes=st.lists(st.integers(1, 3), min_size=1, max_size=3),
        cap_frac=st.floats(0.45, 0.9),
        grant_idx=st.integers(0, len(GRANT_POLICIES) - 1),
        share_idx=st.integers(0, len(FACILITY_SHARE_POLICIES) - 1),
        seed=st.integers(0, 10),
    )
    def test_granted_ledger_peak_under_cap(self, sizes, cap_frac,
                                           grant_idx, share_idx, seed):
        f = _fixture()
        testbed, fitted, app_feats = (f["testbed"], f["predictor"],
                                      f["features"])
        n_dev = sum(sizes)
        jobs = list(multi_rack_workload(APPS, testbed, n_devices=n_dev,
                                        n_jobs=24, seed=seed))
        r0 = run_schedule(jobs, "min-energy", Testbed(seed=1000),
                          predictor=fitted, app_features=app_feats,
                          n_devices=n_dev)
        idle_w = testbed.idle_power()
        led0 = PowerTelemetry.from_result(r0, idle_powers=idle_w,
                                          n_devices=n_dev)
        idle = idle_w * n_dev
        cap = idle + cap_frac * max(led0.peak_w - idle, 1.0)
        fac = FacilityCoordinator(
            cap, sizes, grant_policy=GRANT_POLICIES[grant_idx],
            share_policy=FACILITY_SHARE_POLICIES[share_idx])
        r = run_schedule(jobs, "min-energy", Testbed(seed=1000),
                         predictor=fitted, app_features=app_feats,
                         n_devices=n_dev, power_coordinator=fac)
        for view in ("granted", "measured"):
            led = PowerTelemetry.from_result(
                r, idle_powers=idle_w, n_devices=n_dev, view=view)
            assert led.peak_w <= cap * (1 + 1e-9) + 1e-6, \
                (sizes, cap_frac, view)
        # per-rack caps never sum above the facility cap at the end
        assert math.fsum(fac.caps()) <= cap * (1 + 1e-9) + 1e-6
        # no device ran two jobs at once, and racks partition devices
        by_dev: dict[int, list] = {}
        for rec in r.records:
            assert fac.rack_of(rec.device) == rec.rack
            by_dev.setdefault(rec.device, []).append((rec.start, rec.end))
        for spans in by_dev.values():
            spans.sort()
            for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
                assert s2 >= e1 - 1e-9


# ---------------------------------------------------------------------- #
#  Straggler mechanics: boost ladder, quarantine, migration billing
# ---------------------------------------------------------------------- #
class TestFederatedPreemptionUnit:
    def _mgr(self, sizes=(2, 2), **kw):
        kw.setdefault("dvfs", V5E_DVFS)
        return FederatedPreemptionManager(sizes, **kw)

    def test_slowdown_injection(self):
        mgr = self._mgr(device_slowdown={1: 2.5})
        assert mgr.slowdown_of(1) == 2.5
        assert mgr.slowdown_of(0) == 1.0

    def test_mitigate_clock_identity_when_healthy(self):
        mgr = self._mgr()
        clk = V5E_DVFS.default_clock
        # unflagged device: the SAME object comes back (engine keys its
        # recompute on identity)
        assert mgr.mitigate_clock(0, clk, None) is clk

    def test_mitigate_clock_climbs_ladder(self):
        mgr = self._mgr()
        # flag device 1 via observations
        for _ in range(12):
            mgr.note_step(1, observed_s=3.0, predicted_s=1.0)
            mgr.note_step(0, observed_s=1.0, predicted_s=1.0)
        assert 1 in mgr.monitor.flagged
        clk = ClockPair(min(V5E_DVFS.core_scales), 1.0)
        seen = [clk.s_core]
        for _ in range(len(V5E_DVFS.core_scales) + 2):
            nxt = mgr.mitigate_clock(1, clk, None)
            if nxt.s_core == seen[-1]:
                break
            seen.append(nxt.s_core)
        # strictly climbing, reaches the top rung, then pins there
        assert seen == sorted(set(seen))
        assert mgr.monitor.boosts[1].s_core == max(V5E_DVFS.core_scales)
        assert mgr.monitor.should_evict(1)

    def test_foreign_ladder_never_boosted(self):
        mgr = self._mgr()
        for _ in range(12):
            mgr.note_step(1, observed_s=3.0, predicted_s=1.0)
        clk = ClockPair(min(V5E_DVFS.core_scales), 1.0)
        import dataclasses as dc
        foreign = dc.replace(V5E_DVFS,
                             core_scales=(0.5, 1.0))
        assert mgr.mitigate_clock(1, clk, foreign) is clk

    def test_retire_quarantines_but_never_strands(self):
        mgr = self._mgr(sizes=(1, 1))
        assert mgr.retire("rescue-migration", 0) is True
        assert mgr.quarantined == frozenset({0})
        # last in-service device must stay
        assert mgr.retire("rescue-migration", 1) is False
        assert mgr.quarantined == frozenset({0})
        # non-migration reasons never retire
        assert mgr.retire("cap-rescue", 1) is False

    def test_reset_clears_quarantine_and_monitor(self):
        mgr = self._mgr(sizes=(1, 1))
        mgr.retire("rescue-migration", 0)
        for _ in range(12):
            mgr.note_step(1, observed_s=3.0, predicted_s=1.0)
        mgr.reset()
        assert mgr.quarantined == frozenset()
        assert mgr.monitor.flagged == []
        assert mgr.fed.observations == 0

    def test_migration_cost_same_rack_free(self):
        mgr = self._mgr(sizes=(2, 2))
        job = object.__new__(type("J", (), {}))  # placeholder identity
        mgr._prev_dev[id(job)] = 0
        assert mgr.migration_cost(job, 1) == (0.0, 0.0, None)

    def test_migration_cost_cross_rack_billed(self):
        mgr = self._mgr(sizes=(2, 2))

        class _App:
            hbm_bytes = 4e9

        class _Job:
            app = _App()

        job = _Job()
        mgr._prev_dev[id(job)] = 0
        secs, joules, src = mgr.migration_cost(job, 2)
        exp_s, exp_j = mgr.cost_model.cost(4e9)
        assert (secs, joules, src) == (pytest.approx(exp_s),
                                       pytest.approx(exp_j), 0)
        assert mgr.fed.migration_s == pytest.approx(exp_s)
        assert mgr.fed.migration_j == pytest.approx(exp_j)

    def test_unknown_provenance_is_free(self):
        mgr = self._mgr(sizes=(2, 2))
        class _Job:
            pass
        assert mgr.migration_cost(_Job(), 2) == (0.0, 0.0, None)
