"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting shapes and finiteness; plus
prefill/decode agreement with teacher forcing (the serving path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_ALIASES, all_configs, get_config
from repro.configs.base import SHAPES, reduce_for_smoke, shape_applicable
from repro.models import model
from repro.optim import adamw
from repro.train.step import make_train_step

ARCHS = sorted(ARCH_ALIASES)


@pytest.fixture(scope="module")
def smoke_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduce_for_smoke(get_config(arch))
            params = model.init(cfg, jax.random.PRNGKey(0))
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch, smoke_state):
    cfg, params = smoke_state(arch)
    B, S = 2, 16
    Stext = model.text_len(cfg, S)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, Stext), 0,
                                cfg.vocab_size)
    extra = model.extra_inputs(cfg, B, S, "train", rng=jax.random.PRNGKey(2))
    logits, aux = model.forward(cfg, params, tokens, extra)
    expect_S = S if cfg.family == "vlm" else Stext
    assert logits.shape == (B, expect_S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, smoke_state):
    cfg, params = smoke_state(arch)
    B, S = 2, 16
    Stext = model.text_len(cfg, S)
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = adamw.init(params, ocfg)
    step = jax.jit(make_train_step(cfg, ocfg))
    rng = jax.random.PRNGKey(3)
    batch = {
        "tokens": jax.random.randint(rng, (B, Stext), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (B, Stext), 0, cfg.vocab_size),
    }
    batch.update(model.extra_inputs(cfg, B, S, "train",
                                    rng=jax.random.PRNGKey(4)))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, new_params)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch, smoke_state):
    cfg, params = smoke_state(arch)
    B, S = 2, 12
    Stext = model.text_len(cfg, S)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, Stext), 0,
                                cfg.vocab_size)
    extra = model.extra_inputs(cfg, B, S, "train", rng=jax.random.PRNGKey(2))
    logits_full, _ = model.forward(cfg, params, tokens, extra)
    pre = tokens[:, :Stext - 1]
    _, cache = model.prefill(cfg, params, pre, max_seq=S + 4, extra=extra,
                             cache_dtype=jnp.float32)
    pos = (S - 2) if cfg.family == "vlm" else (Stext - 2)
    logits_dec, _ = model.decode_step(cfg, params, cache,
                                      tokens[:, Stext - 1:Stext],
                                      jnp.int32(pos + 1))
    err = float(jnp.max(jnp.abs(logits_full[:, -1] - logits_dec[:, 0])))
    assert err < 2e-2, f"{arch}: decode/forward mismatch {err}"


@pytest.mark.parametrize("arch", ARCHS)
def test_param_spec_tree_matches_params(arch, smoke_state):
    """The PartitionSpec tree must mirror the param tree exactly."""
    cfg, params = smoke_state(arch)
    specs = model.param_specs(cfg)
    pt = jax.tree.structure(params)
    st = jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert pt == st, f"{arch}: spec treedef != param treedef"
    # every spec's rank must be <= the param's rank
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    for p, s in zip(flat_p, flat_s):
        assert len(tuple(s)) <= p.ndim, (arch, p.shape, s)


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_spec_tree_matches_cache(arch, smoke_state):
    cfg, params = smoke_state(arch)
    cache = model.init_cache(cfg, batch=2, max_seq=16)
    specs = model.cache_specs(cfg)
    pt = jax.tree.structure(cache)
    st = jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert pt == st


def test_shape_applicability_table():
    """DESIGN.md §5: long_500k runs only for sub-quadratic archs."""
    expect_long = {"zamba2-7b", "falcon-mamba-7b", "mixtral-8x22b"}
    for arch in ARCHS:
        cfg = get_config(arch)
        ok, why = shape_applicable(cfg, SHAPES["long_500k"])
        assert ok == (arch in expect_long), (arch, why)
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(cfg, SHAPES[s])[0]


def test_param_counts_match_public_numbers():
    expected = {  # billions, ±12% (frontends stubbed, heads untied, etc.)
        "stablelm-3b": 2.8, "qwen2.5-14b": 14.8, "smollm-360m": 0.36,
        "mistral-nemo-12b": 12.2, "internvl2-76b": 70.0, "zamba2-7b": 7.0,
        "falcon-mamba-7b": 7.3, "mixtral-8x22b": 141.0,
        "kimi-k2-1t-a32b": 1030.0, "whisper-large-v3": 2.0,
    }
    for arch, exp in expected.items():
        got = get_config(arch).param_count() / 1e9
        assert abs(got - exp) / exp < 0.12, (arch, got, exp)


def test_moe_capacity_drop_behavior():
    """With tight capacity, tokens are dropped, output stays finite, and the
    residual path keeps the dropped positions' activations."""
    import dataclasses
    from repro.models.moe import init_moe, moe
    cfg = dataclasses.replace(
        reduce_for_smoke(get_config("mixtral-8x22b")), capacity_factor=0.25)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    out, aux = moe(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux) >= 1.0  # LB loss lower bound is 1 at perfect balance
