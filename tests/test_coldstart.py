"""Property battery for the cold-start synthesis tier (PR 8).

Three pinned properties from the issue, plus lifecycle/unit coverage:

* **Identity** — with zero unseen apps, attaching a synthesizer is
  bit-identical to the plain engine for all six policies (invariant #10,
  the identity-oracle pattern of test_tenants.py / test_differential.py).
* **Ladder shape** — synthesized (P, T) tables are finite and positive,
  and T is monotone non-increasing in core clock at fixed mem clock on
  every stock :class:`~repro.core.dvfs.DeviceClass` ladder, for
  hypothesis-random static counters.
* **Corrector convergence** — the PR 2 RLS corrector refines synthesized
  tables toward a perturbed ground truth, and the corrected table is
  order-independent under observation-stream permutation (commutative
  sufficient statistics).
"""
import dataclasses
import itertools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # not installed in this container — deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.paper_suite import PAPER_APPS
from repro.core import (
    AppProfile, ColdStartConfig, ColdStartSynthesizer, DEVICE_CLASSES,
    EnergyTimePredictor, Observation, ObservationStore, PredictionService,
    PredictorConfig, RLSCorrector, Testbed, V5E_DVFS, build_dataset,
    profile_features, run_schedule, static_features, stream_workload,
)
from repro.core.coldstart import SMOOTH_P
from repro.core.features import FEATURE_NAMES
from repro.core.gbdt import GBDTParams
from repro.core.online import clock_basis
from repro.core.policies import POLICY_NAMES

APPS = list(PAPER_APPS)[:8]
SMALL = PredictorConfig(
    gbdt=GBDTParams(iterations=80, depth=3, learning_rate=0.15,
                    l2_leaf_reg=5.0),
    gbdt_time=GBDTParams(iterations=80, depth=3, learning_rate=0.15,
                         l2_leaf_reg=3.0),
)


@pytest.fixture(scope="module")
def testbed():
    return Testbed(seed=0)


@pytest.fixture(scope="module")
def fitted(testbed):
    X, yp, yt, _ = build_dataset(APPS, testbed, seed=0)
    return EnergyTimePredictor(SMALL).fit(X, yp, yt)


@pytest.fixture(scope="module")
def app_feats(testbed):
    rng = np.random.default_rng(7)
    return {a.name: profile_features(a, testbed, rng=rng) for a in APPS}


def _service(testbed, fitted, app_feats) -> PredictionService:
    return PredictionService(V5E_DVFS, predictor=fitted,
                             app_features=dict(app_feats), testbed=testbed)


def _rand_app(rng: np.random.Generator, i: int = 0) -> AppProfile:
    return AppProfile(
        name=f"h-{i}",
        flops=10.0 ** rng.uniform(10.0, 15.0),
        hbm_bytes=10.0 ** rng.uniform(8.0, 12.5),
        coll_bytes=float(rng.choice([0.0, 10.0 ** rng.uniform(6.0, 11.0)])),
        overhead_s=float(rng.uniform(0.0, 2.0)),
        kind=str(rng.choice(["kernel", "train", "prefill", "decode"])),
        n_chips=int(rng.choice([1, 4, 16])))


# ---------------------------------------------------------------------- #
#  Property (a): zero unseen apps => bit-identity, all six policies
# ---------------------------------------------------------------------- #
class TestZeroUnseenIdentity:
    def test_all_policies_bit_identical(self, testbed, fitted, app_feats):
        """Invariant #10: an attached synthesizer never changes
        profiled-app decisions — same records, same RNG draws."""
        jobs = list(stream_workload(APPS, testbed, n_jobs=40, seed=5,
                                    n_devices=2))
        for pol in POLICY_NAMES:
            plain = run_schedule(jobs, pol, Testbed(seed=200),
                                 service=_service(testbed, fitted,
                                                  app_feats), n_devices=2)
            cold = run_schedule(jobs, pol, Testbed(seed=200),
                                service=_service(testbed, fitted, app_feats),
                                n_devices=2,
                                coldstart=ColdStartSynthesizer())
            assert cold.records == plain.records, pol
            assert cold.total_energy == plain.total_energy, pol

    def test_synthesizer_untouched_when_all_profiled(self, testbed, fitted,
                                                     app_feats):
        synth = ColdStartSynthesizer()
        jobs = list(stream_workload(APPS, testbed, n_jobs=30, seed=6,
                                    n_devices=1))
        run_schedule(jobs, "min-energy", Testbed(seed=201),
                     service=_service(testbed, fitted, app_feats),
                     coldstart=synth)
        assert synth.stats.registered == 0
        assert synth.stats.synthesized_tables == 0


# ---------------------------------------------------------------------- #
#  Property (b): ladder shape on every stock DeviceClass
# ---------------------------------------------------------------------- #
class TestSynthesizedLadderShape:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_finite_positive_monotone(self, seed):
        """Synthesized (P, T) finite and positive; T monotone
        non-increasing in core clock at fixed mem clock, on every stock
        device-class ladder, for random static counters — with and
        without a profiled corpus behind the κ-transfer."""
        rng = np.random.default_rng(seed)
        app = _rand_app(rng, seed)
        synth = ColdStartSynthesizer(dvfs=V5E_DVFS)
        synth.register(app)
        for cls in DEVICE_CLASSES.values():
            d = cls.dvfs
            clocks = d.clock_list()
            P, T = synth.synthesize(app.name, clocks, d)
            assert np.all(np.isfinite(P)) and np.all(np.isfinite(T))
            assert np.all(P > 0) and np.all(T > 0)
            for s_mem, group in itertools.groupby(
                    zip(clocks, T), key=lambda ct: ct[0].s_mem):
                ladder = [t for _, t in group]   # core-ascending per block
                for lo, hi in zip(ladder, ladder[1:]):
                    assert hi <= lo * (1.0 + 1e-9), (cls.name, s_mem)

    def test_kappa_transfer_preserves_shape(self, testbed, fitted,
                                            app_feats):
        """Same shape properties when κ comes from a profiled neighbor
        (service-backed path) instead of the κ=1 analytic prior."""
        svc = _service(testbed, fitted, app_feats)
        synth = ColdStartSynthesizer()
        svc.attach_synthesizer(synth)
        rng = np.random.default_rng(3)
        for i in range(5):
            app = _rand_app(rng, i)
            assert svc.note_app(app)
            assert synth.neighbor(app.name) in app_feats
            tab = svc.base_table(app.name)
            assert tab.source == "synthesized"
            assert np.all(np.isfinite(tab.P)) and np.all(tab.P > 0)
            assert np.all(np.isfinite(tab.T)) and np.all(tab.T > 0)

    def test_static_features_shape_and_finiteness(self):
        rng = np.random.default_rng(11)
        for i in range(10):
            v = static_features(_rand_app(rng, i), V5E_DVFS)
            assert v.shape == (len(FEATURE_NAMES),)
            assert np.all(np.isfinite(v))


# ---------------------------------------------------------------------- #
#  Property (c): corrector convergence + order independence
# ---------------------------------------------------------------------- #
class TestCorrectorOverSynthesized:
    def _synth_table(self):
        synth = ColdStartSynthesizer(dvfs=V5E_DVFS)
        synth.register(AppProfile(name="cold-app", flops=5e13,
                                  hbm_bytes=2e11, overhead_s=0.1))
        clocks = V5E_DVFS.clock_list()
        P, T = synth.synthesize("cold-app", clocks, V5E_DVFS)
        return clocks, P, T

    def test_convergence_toward_truth(self):
        """Feeding residuals of a multiplicatively-biased ground truth
        shrinks the corrected table's error well below the frozen
        synthesized prior's."""
        clocks, P, T = self._synth_table()
        w_true = np.array([0.35, -0.2, 0.1])    # log-bias on [1, sc, sm]
        T_true = T * np.exp([w_true @ clock_basis(ck) for ck in clocks])
        store = ObservationStore()
        corr = RLSCorrector(store)
        rng = np.random.default_rng(0)
        for i in rng.choice(len(clocks), size=40):
            ck = clocks[i]
            store.update(Observation(
                name="cold-app", clock=ck, time_s=float(T_true[i]),
                power_w=1.0, r_time=float(np.log(T_true[i] / T[i])),
                r_power=0.0))
        _, T_corr = corr.correct("cold-app", clocks, P, T)
        err_frozen = np.abs(np.log(T / T_true)).mean()
        err_corr = np.abs(np.log(T_corr / T_true)).mean()
        assert err_corr < 0.2 * err_frozen

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_order_independence(self, seed):
        """Any permutation of the same observation multiset yields the
        same corrected table (commutative sufficient statistics)."""
        clocks, P, T = self._synth_table()
        rng = np.random.default_rng(seed)
        obs = [Observation(name="cold-app", clock=clocks[i], time_s=1.0,
                           power_w=1.0, r_time=float(rng.normal(0.2, 0.1)),
                           r_power=float(rng.normal(-0.1, 0.05)))
               for i in rng.choice(len(clocks), size=16)]
        tables = []
        for perm_seed in (1, 2):
            store = ObservationStore()
            order = np.random.default_rng(perm_seed).permutation(len(obs))
            for j in order:
                store.update(obs[j])
            tables.append(RLSCorrector(store).correct(
                "cold-app", clocks, P, T))
        np.testing.assert_allclose(tables[0][1], tables[1][1], rtol=1e-9)
        np.testing.assert_allclose(tables[0][0], tables[1][0], rtol=1e-9)


# ---------------------------------------------------------------------- #
#  Lifecycle + service integration
# ---------------------------------------------------------------------- #
class TestLifecycle:
    def test_cold_to_warmed_promotion(self, testbed, fitted, app_feats):
        svc = _service(testbed, fitted, app_feats)
        synth = ColdStartSynthesizer(config=ColdStartConfig(warm_after=3))
        svc.attach_synthesizer(synth)
        app = _rand_app(np.random.default_rng(1), 0)
        assert synth.status(app.name) == "unknown"
        svc.note_app(app)
        assert synth.status(app.name) == "cold"
        for _ in range(3):
            svc.invalidate(app.name)    # observation-driven invalidation
        assert synth.status(app.name) == "warmed"
        assert synth.stats.promotions == 1

    def test_register_idempotent(self):
        synth = ColdStartSynthesizer(dvfs=V5E_DVFS)
        app = _rand_app(np.random.default_rng(2), 0)
        assert synth.register(app)
        assert not synth.register(app)
        assert synth.stats.registered == 1

    def test_note_app_noop_for_profiled(self, testbed, fitted, app_feats):
        svc = _service(testbed, fitted, app_feats)
        svc.attach_synthesizer(ColdStartSynthesizer())
        assert not svc.note_app(APPS[0])    # profiled: zero-unseen no-op
        assert svc.synthesizer.stats.registered == 0

    def test_detach_restores_strictness(self, testbed, fitted, app_feats):
        from repro.core import UnknownAppError
        svc = _service(testbed, fitted, app_feats)
        svc.attach_synthesizer(ColdStartSynthesizer())
        app = _rand_app(np.random.default_rng(4), 0)
        svc.note_app(app)
        assert svc.base_table(app.name).source == "synthesized"
        svc.detach_synthesizer()
        with pytest.raises(UnknownAppError):
            svc.table(app.name)

    def test_mixed_stream_end_to_end(self, testbed, fitted, app_feats):
        """Unseen apps mid-stream schedule without raising; their records
        exist; synthesized tables were actually served (non-vacuity)."""
        novel = [dataclasses.replace(APPS[i], name=f"novel-{i}",
                                     seed=900 + i, core_eff=0.6)
                 for i in range(3)]
        jobs = list(stream_workload(APPS + novel, testbed, n_jobs=60,
                                    seed=9, n_devices=2))
        svc = _service(testbed, fitted, app_feats)
        synth = ColdStartSynthesizer()
        res = run_schedule(jobs, "min-energy", Testbed(seed=300),
                           service=svc, n_devices=2, coldstart=synth)
        assert len(res.records) == len(jobs)
        assert synth.stats.registered == 3
        assert svc.stats.synthesized_builds >= 1
        assert {r.name for r in res.records} >= {a.name for a in novel}
