"""Serve a small model with batched requests: prefill + autoregressive decode
with the KV cache (ring-buffer windowed cache for SWA archs).

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch mixtral-8x22b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import reduce_for_smoke
from repro.core.model_apps import derive_app
from repro.models import model
from repro.train.serve import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    print(f"arch={cfg.name} family={cfg.family} "
          f"(reduced config for CPU serving demo)")
    for phase in ("prefill", "decode"):
        app = derive_app(args.arch, phase)
        print(f"scheduler app: {app.name} (flops={app.flops:.3g} "
              f"hbm={app.hbm_bytes:.3g}B n_chips={app.n_chips}, "
              f"full-size counters the DVFS scheduler dispatches on)")
    params = model.init(cfg, jax.random.PRNGKey(0))

    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)
    max_seq = args.prompt_len + args.gen + 8

    t0 = time.time()
    out = greedy_generate(cfg, params, prompt, n_steps=args.gen,
                          max_seq=max_seq)
    dt = time.time() - t0
    print(f"prefill({args.batch}x{args.prompt_len}) + decode {args.gen} "
          f"steps in {dt:.1f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s on 1 CPU core)")
    print("generated token ids (first request):", out[0].tolist())

    # consistency: teacher-forcing forward over prompt+generated reproduces
    # the same greedy continuation
    full = jnp.concatenate([prompt[:1], out[:1]], axis=1)
    Stext = model.text_len(cfg, full.shape[1])
    logits, _ = model.forward(cfg, params, full[:, :Stext],
                              model.extra_inputs(cfg, 1, full.shape[1]))
    redo = jnp.argmax(logits[0, args.prompt_len - 1:-1], axis=-1)
    agree = float(jnp.mean((redo == out[0]).astype(jnp.float32)))
    print(f"teacher-forcing agreement with decode path: {100*agree:.0f}%")


if __name__ == "__main__":
    main()
