"""End-to-end driver: deadline-aware DVFS scheduling of REAL framework jobs.

The jobs are the assigned architectures' training/serving steps. Their
resource profiles (FLOPs, HBM bytes, collective bytes per step) come from the
multi-pod dry-run's compiled artifacts (results/dryrun_single.json) — the
TPU-native "nvprof" of DESIGN.md §2 — so the scheduler is setting clocks for
the exact workloads the framework runs. Falls back to four built-in profiles
when the dry-run cache is absent.

Run:  PYTHONPATH=src python examples/schedule_jobs.py [--steps 20]
"""
import argparse
import json
import os

import numpy as np

from repro.core import (AppProfile, EnergyTimePredictor, PredictionService,
                        PredictorConfig, Testbed, build_dataset,
                        make_workload, profile_features, run_schedule)
from repro.configs.paper_suite import PAPER_APPS

_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
RESULTS = next((os.path.join(_DIR, f) for f in
                ("dryrun_final.json", "dryrun_single.json")
                if os.path.exists(os.path.join(_DIR, f))),
               os.path.join(_DIR, "dryrun_final.json"))

_FALLBACK = [
    # (name, flops/dev/step, bytes/dev/step, coll bytes/dev/step, kind)
    ("qwen2.5-14b/train_4k", 1.5e12, 4.0e11, 9.0e10, "train"),
    ("smollm-360m/train_4k", 2.0e13, 1.6e12, 2.9e10, "train"),
    ("mixtral-8x22b/decode_32k", 1.6e11, 2.8e10, 2.4e9, "decode"),
    ("falcon-mamba-7b/long_500k", 2.1e9, 6.3e9, 1.6e9, "decode"),
]


def arch_apps(steps: int) -> list[AppProfile]:
    """One AppProfile per (arch x shape) job: `steps` steps per job."""
    rows = []
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            cells = json.load(f)
        for c in cells:
            if c.get("status") == "ok" and "roofline" in c:
                rl = c["roofline"]
                rows.append((f"{c['arch']}/{c['shape']}", rl["flops"],
                             rl["bytes_accessed"], rl["coll_bytes_modeled"],
                             "train" if "train" in c["shape"] else "decode"))
    if not rows:
        rows = _FALLBACK
    apps = []
    for i, (name, fl, by, co, kind) in enumerate(rows):
        apps.append(AppProfile(
            name=name, flops=fl * steps, hbm_bytes=by * steps,
            coll_bytes=co * steps, overhead_s=0.05 * steps, kind=kind,
            n_chips=256, wiggle_time=0.03, wiggle_power=0.03,
            seed=500 + i))
    return apps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20,
                    help="train/serve steps per scheduled job")
    ap.add_argument("--jobs", type=int, default=16)
    args = ap.parse_args()

    testbed = Testbed(seed=0)
    apps = arch_apps(args.steps)[:args.jobs]
    print(f"scheduling {len(apps)} framework jobs "
          f"({args.steps} steps each):")
    for a in apps[:8]:
        print(f"  {a.name:34s} {a.flops/1e12:8.1f} TFLOP  "
              f"{a.hbm_bytes/1e9:8.1f} GB  AI={a.arithmetic_intensity:6.1f}")

    # the predictors are trained on the paper suite + these jobs' profiles
    train_apps = list(PAPER_APPS) + apps
    X, yp, yt, _ = build_dataset(train_apps, testbed, seed=0)
    predictor = EnergyTimePredictor(PredictorConfig()).fit(X, yp, yt)
    rng = np.random.default_rng(7)
    feats = {a.name: profile_features(a, testbed, rng=rng)
             for a in train_apps}

    jobs = make_workload(apps, testbed, seed=1,
                         arrival_range=(1.0, 120.0))
    # one shared prediction service: the app × clock-ladder tables are
    # built once and reused by every policy below (run_schedule wires the
    # EventEngine + default budget managers around it)
    run_tb = Testbed(seed=42)
    service = PredictionService(run_tb.dvfs, predictor=predictor,
                                app_features=feats, testbed=run_tb)
    print()
    for policy in ("mc", "dc", "d-dvfs", "oracle"):
        r = run_schedule(jobs, policy, run_tb, service=service)
        # fleet energy = per-chip energy x chips
        print(f"  {policy:7s} per-chip E={r.total_energy:9.1f} J  "
              f"fleet E={r.total_energy*256/3.6e6:7.2f} kWh  "
              f"misses={r.misses}")
    print(f"\n  prediction service: {service.stats.summary()}")


if __name__ == "__main__":
    main()
