"""Train a small LM for a few hundred steps with the full substrate:
synthetic data pipeline, AdamW, remat, async checkpointing, fault-tolerant
runner with an injected failure + bit-exact restart.

Default: a ~55M-param llama-style model (SmolLM family), 200 steps on CPU.
Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--dim 512]
"""
import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.model_apps import derive_app
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist.fault_tolerance import (FailureInjector, RunnerConfig,
                                        TrainingRunner)
from repro.models import model
from repro.optim import adamw
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--inject-failure", action="store_true", default=True)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("smollm-360m"),
        n_layers=args.layers, d_model=args.dim, n_heads=8, n_kv_heads=4,
        head_dim=args.dim // 8, d_ff=args.dim * 4, vocab_size=args.vocab,
        param_dtype="float32", activation_dtype="float32", remat="none")
    n_params = cfg.param_count()
    print(f"model: {args.layers}L d={args.dim} vocab={args.vocab} "
          f"→ {n_params/1e6:.1f}M params")
    app = derive_app("smollm-360m", "train_step")
    print(f"scheduler app: {app.name} (flops={app.flops:.3g} "
          f"hbm={app.hbm_bytes:.3g}B coll={app.coll_bytes:.3g}B "
          f"n_chips={app.n_chips}, full-size counters the DVFS "
          f"scheduler dispatches on)")

    params = model.init(cfg, jax.random.PRNGKey(0))
    ocfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=20,
                             total_steps=args.steps, weight_decay=0.01)
    opt = adamw.init(params, ocfg)
    step = jax.jit(make_train_step(cfg, ocfg))

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch, seed=0,
                                  order=1))

    # keyed by step so checkpoint-restart replays overwrite, not duplicate
    history = {}
    cur_step = {"s": 0}

    def data_fn(s):
        cur_step["s"] = s
        return {k: jnp.asarray(v) for k, v in data.batch(s).items()}

    def step_fn(p, o, batch):
        p, o, m = step(p, o, batch)
        history[cur_step["s"]] = float(m["loss"])
        return p, o, m

    ckpt_dir = tempfile.mkdtemp(prefix="repro_train_lm_")
    injector = FailureInjector(fail_at=(args.steps // 2,)) \
        if args.inject_failure else None
    runner = TrainingRunner(
        RunnerConfig(ckpt_dir=ckpt_dir, ckpt_interval=50),
        step_fn, data_fn, injector=injector)

    t0 = time.time()
    params, opt, _ = runner.run(params, opt, 0, args.steps)
    dt = time.time() - t0
    losses = [history[s] for s in sorted(history)]
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    tok_s = args.batch * args.seq * len(losses) / dt
    print(f"steps={len(losses)} restarts={runner.restarts} wall={dt:.0f}s "
          f"({tok_s:.0f} tok/s)")
    print(f"loss: {first:.3f} → {last:.3f} "
          f"(uniform = {np.log(args.vocab):.3f})")
    assert last < first - 0.2, "loss did not improve"
    print("OK: loss decreased; failure was injected and recovered" if
          runner.restarts else "OK: loss decreased")


if __name__ == "__main__":
    main()
