"""Quickstart: the paper's pipeline end to end in ~1 minute on CPU.

1. Profile the 12-application suite on the simulated DVFS testbed.
2. Train the CatBoost-style power & time predictors.
3. Schedule a deadline workload with Algorithm 1 (D-DVFS) vs DC/MC.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.paper_suite import PAPER_APPS
from repro.core import (EnergyTimePredictor, PredictorConfig, Testbed,
                        build_dataset, make_workload, profile_features,
                        run_schedule)


def main():
    testbed = Testbed(seed=0)
    apps = list(PAPER_APPS)

    print("== 1. profiling campaign (12 apps x 64 clock pairs) ==")
    X, y_power, y_time, groups = build_dataset(apps, testbed, seed=0)
    print(f"   dataset: {X.shape[0]} rows x {X.shape[1]} features")

    print("== 2. train power/time predictors (oblivious-tree GBDT) ==")
    predictor = EnergyTimePredictor(PredictorConfig()).fit(X, y_power, y_time)
    rng = np.random.default_rng(7)
    feats = {a.name: profile_features(a, testbed, rng=rng) for a in apps}

    print("== 3. deadline-aware scheduling ==")
    jobs = make_workload(apps, testbed, seed=0)
    results = {}
    for policy in ("mc", "dc", "d-dvfs"):
        r = run_schedule(jobs, policy, Testbed(seed=100),
                         predictor=predictor, app_features=feats)
        results[policy] = r
        print(f"   {policy:7s} energy={r.total_energy:7.1f} J  "
              f"misses={r.misses}  makespan={r.makespan:5.1f} s")
    dd, dc, mc = (results[p].total_energy for p in ("d-dvfs", "dc", "mc"))
    print(f"\nD-DVFS saves {100*(1-dd/dc):.1f}% vs DC and "
          f"{100*(1-dd/mc):.1f}% vs MC with {results['d-dvfs'].misses} "
          f"deadline misses (paper: 13.8% / 25.2%, zero misses).")


if __name__ == "__main__":
    main()
